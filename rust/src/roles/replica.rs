//! State machine replicas (§4.1, §5.3) and the state-retention subsystem.
//!
//! Replicas insert chosen commands into their logs, execute the log in
//! prefix order against a pluggable [`crate::statemachine::StateMachine`],
//! and send execution results back to clients. They acknowledge their
//! contiguous stored prefix to the leader (`ReplicaAck`), which drives GC
//! Scenario 3 (a prefix stored on `f+1` replicas may be garbage
//! collected), and they serve `ReadPrefix` so a newly elected leader can
//! learn the chosen prefix (§4.1: "by communicating with the replicas").
//!
//! With an enabled [`SnapshotSpec`], replicas additionally bound their
//! durable state: every `interval` they snapshot the state machine (plus
//! the client dedup table, so exactly-once survives a snapshot install),
//! truncate the chosen log below the snapshot watermark keeping a
//! retained tail of `tail` entries, and serve snapshot-plus-tail
//! catch-up ([`Msg::SnapshotRequest`]/[`Msg::SnapshotResp`]) to lagging
//! or freshly joined peers that the leader points at them
//! ([`Msg::CatchUp`]). This is the replica half of the paper's GC story:
//! matchmakers and acceptors retire configuration/vote state (§5), and
//! replicas retire the chosen log itself.

use crate::codec::{Dec, Enc};
use crate::config::SnapshotSpec;
use crate::msg::{Command, Msg, Value};
use crate::node::{Announce, Effects, Node, Timer};
use crate::statemachine::StateMachine;
use crate::storage::{Storage, WalRecord};
use crate::{GroupId, NodeId, Slot, Time, MS, SEC};
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Per-client execution history: dedup cursor plus a bounded window of
/// recent results. Pipelined clients can lose the reply to seq `k` while
/// seqs `k+1..` already executed, so caching only the latest result is
/// not enough to answer retries of any recently executed request.
#[derive(Debug, Default)]
pub struct ClientHistory {
    /// Highest executed seq for this client (commands at or below it are
    /// duplicates, never re-executed).
    pub highest: u64,
    /// Results of the most recent [`RESULT_CACHE`] executed seqs, tagged
    /// with the slot they executed at so truncation can also retire them
    /// by watermark (see [`Replica::snapshot`]).
    pub recent: BTreeMap<u64, (Slot, Vec<u8>)>,
}

/// How long a replica waits for a `SnapshotResp` before re-requesting
/// (the response may be lost on a lossy network).
const CATCHUP_RETRY: Time = 50 * MS;

/// Default size of one `SnapshotChunk` payload. Large enough that the
/// per-chunk overhead is negligible, small enough that a chunk never
/// approaches the network frame cap ([`crate::net`]'s `MAX_FRAME`) no
/// matter how big the snapshotted state grows.
const SNAPSHOT_CHUNK: usize = 256 << 10;

/// Retry ticks a chunk assembly may sit with no new chunk before it is
/// abandoned (the sender likely died mid-stream) and catch-up falls
/// back to rotating `SnapshotRequest`s. The first silent tick resumes
/// the stream via `SnapshotResume` instead of giving up — one lost
/// chunk must not restart a multi-megabyte transfer from scratch.
const MAX_RESUME_STALLS: u32 = 3;

/// An in-progress chunked snapshot transfer (receiver side). Chunks
/// are applied strictly in order; `next_seq` doubles as the resume
/// cursor sent in [`Msg::SnapshotResume`] when the stream stalls.
#[derive(Debug)]
struct ChunkAssembly {
    /// Peer streaming the snapshot.
    peer: NodeId,
    /// Snapshot base: the assembled state covers slots `< base`.
    base: Slot,
    /// Total chunks in this transfer.
    total: u32,
    /// Next expected chunk seq (== number of chunks received).
    next_seq: u32,
    /// Assembled snapshot bytes.
    buf: Vec<u8>,
    /// Consecutive retry ticks without progress (see
    /// [`MAX_RESUME_STALLS`]).
    stalls: u32,
    /// `next_seq` observed at the previous retry tick (progress
    /// detector: a flowing stream never triggers a resume).
    seq_at_last_tick: u32,
}

/// How often pending reads are re-driven: a lost `ReadIndexReq`/`Resp`
/// is re-sent (rotating the leader target) and lapsed-lease reads fall
/// back to the ReadIndex path at this cadence.
const READ_RETRY: Time = 10 * MS;

/// A read that has waited this long for a fresh lease grant falls back
/// to the one-message ReadIndex path (the lease lapsed, or the leader
/// paused grants for an installation).
const READ_GRANT_PATIENCE: Time = 10 * MS;

/// Pending reads older than this are dropped: the client's resend has
/// long since taken the read to another replica, and an unbounded queue
/// would be a memory leak under partition.
const READ_EXPIRE: Time = SEC;

/// Hard bound on the pending-read queue (overload guard; the client's
/// retry path recovers anything shed here).
const MAX_PENDING_READS: usize = 8192;

/// How a pending read is waiting to be served.
#[derive(Clone, Copy, Debug, PartialEq)]
enum ReadState {
    /// Leased fast path: waiting for the first `LeaseGrant` issued at
    /// or after the read arrived (grants carry the chosen watermark and
    /// are pushed continuously, so this costs no per-read messages).
    AwaitGrant,
    /// Fallback: waiting for the `ReadIndexResp` of a request sent at
    /// or after the read arrived.
    AwaitIndex,
    /// Read index resolved: serve once `exec_watermark` covers it.
    Ready(Slot),
}

/// One queued linearizable read.
#[derive(Debug)]
struct PendingRead {
    client: NodeId,
    seq: u64,
    payload: Vec<u8>,
    arrived_at: Time,
    state: ReadState,
}

/// How many per-client results a replica retains for retry re-replies.
/// Covers the largest client in-flight window (workload specs clamp
/// their windows to this bound for exactly that reason).
pub const RESULT_CACHE: usize = crate::workload::MAX_IN_FLIGHT;

/// A state machine replica.
pub struct Replica {
    /// This node's id.
    pub id: NodeId,
    /// The consensus group (shard) this replica belongs to. Client
    /// replies are tagged with it so a shard-routing client can dispatch
    /// them to the right per-group lane. 0 in single-group deployments.
    pub group: GroupId,
    /// Chosen log.
    pub log: BTreeMap<Slot, Value>,
    /// Next slot to execute; slots `< exec_watermark` are executed.
    pub exec_watermark: Slot,
    /// The application state machine.
    pub sm: Box<dyn StateMachine>,
    /// Deduplication + retry re-reply cache, per client.
    pub client_table: HashMap<NodeId, ClientHistory>,
    /// Number of commands executed (metrics).
    pub executed: u64,
    /// Emit an `Announce::Executed` per slot (off by default: it is 3
    /// allocations per command across a 2f+1 replica group on the hottest
    /// path; the TCP integration test and debug tooling enable it).
    pub announce_execs: bool,
    /// Snapshot / truncation policy (disabled by default; the harness and
    /// deployment launcher set it before the node starts).
    pub snapshot: SnapshotSpec,
    /// Peer replicas: snapshot catch-up sources. The leader's `CatchUp`
    /// hint seeds the choice; retries rotate through this list so a dead
    /// hinted peer cannot stall catch-up forever.
    pub peers: Vec<NodeId>,
    /// Slots below this are truncated from `log`, covered by the state
    /// snapshot.
    pub truncated_below: Slot,
    /// Number of periodic snapshots taken (metrics).
    pub snapshots_taken: u64,
    /// Number of peer snapshots installed (metrics).
    pub snapshots_installed: u64,
    /// High-water mark of `log.len()` (metrics: the X5 bounded-memory
    /// acceptance gate reads this).
    pub max_log_len: usize,
    /// The group's proposers, ReadIndex fallback targets (wired by the
    /// harness / deployment launcher like `peers`).
    pub proposers: Vec<NodeId>,
    /// Reads served from a lease grant, no leader round trip (metrics).
    pub reads_leased: u64,
    /// Reads served via the ReadIndex fallback (metrics).
    pub reads_indexed: u64,
    /// Latest lease grant: `(upto, granted_at, valid_until)`. The
    /// validity already discounts the leader's drift bound.
    lease: Option<(Slot, Time, Time)>,
    /// Queued linearizable reads, FIFO by arrival.
    pending_reads: VecDeque<PendingRead>,
    /// Best current-leader guess (sender of the last `Chosen` or
    /// `LeaseGrant`); `proposers[leader_hint]` is the fallback.
    last_leader: Option<NodeId>,
    leader_hint: usize,
    /// Next ReadIndex request id.
    read_req_next: u64,
    /// Outstanding ReadIndex request: `(id, sent_at)`.
    read_req_inflight: Option<(u64, Time)>,
    /// Whether the `ReadIndexRetry` chain is armed.
    read_timer_armed: bool,
    /// Most recent periodic snapshot: `(watermark, serialized state)`.
    last_snapshot: Option<(Slot, Vec<u8>)>,
    /// Active catch-up: `(peer, target watermark, last request time)`.
    /// A retry timer re-issues the request while this is set, so a lost
    /// `SnapshotResp` recovers even with no client traffic flowing.
    catchup: Option<(NodeId, Slot, Time)>,
    /// Whether a `CatchupRetry` timer is outstanding (one chain at a
    /// time, same idiom as the leader's Phase 2 watchdog).
    catchup_timer_armed: bool,
    /// Size of one outgoing `SnapshotChunk` payload (tests shrink it
    /// to force multi-chunk transfers).
    pub chunk_bytes: usize,
    /// In-progress chunked snapshot assembly, if any.
    assembly: Option<ChunkAssembly>,
    /// Durable chosen-log + snapshot store (`None` in sim/model-checker
    /// runs; the TCP runtime attaches a WAL). Every fresh chosen entry
    /// is appended *before* it can influence a `ReplicaAck`, and every
    /// periodic snapshot is stored before the record log is truncated
    /// to the retained tail — so `kill -9` at any instant loses nothing
    /// the replica ever acknowledged (DESIGN.md §Durability).
    storage: Option<Box<dyn Storage>>,
}

impl Replica {
    /// A replica executing chosen commands against `sm`. Snapshotting is
    /// off until [`Replica::snapshot`] is set (with peers for catch-up).
    pub fn new(id: NodeId, sm: Box<dyn StateMachine>) -> Replica {
        Replica {
            id,
            group: 0,
            log: BTreeMap::new(),
            exec_watermark: 0,
            sm,
            client_table: HashMap::new(),
            executed: 0,
            announce_execs: false,
            snapshot: SnapshotSpec::default(),
            peers: Vec::new(),
            truncated_below: 0,
            snapshots_taken: 0,
            snapshots_installed: 0,
            max_log_len: 0,
            proposers: Vec::new(),
            reads_leased: 0,
            reads_indexed: 0,
            lease: None,
            pending_reads: VecDeque::new(),
            last_leader: None,
            leader_hint: 0,
            read_req_next: 0,
            read_req_inflight: None,
            read_timer_armed: false,
            last_snapshot: None,
            catchup: None,
            catchup_timer_armed: false,
            chunk_bytes: SNAPSHOT_CHUNK,
            assembly: None,
            storage: None,
        }
    }

    // =====================================================================
    // Durability (DESIGN.md §Durability)
    // =====================================================================

    /// Attach a durable store. Call before `on_start`; combine with
    /// [`Replica::recover`] when the directory may hold state from a
    /// previous incarnation.
    pub fn attach_storage(&mut self, storage: Box<dyn Storage>) {
        self.storage = Some(storage);
    }

    /// Detach and return the durable store (crash simulation: the
    /// "disk" survives the process, so tests move it into a fresh
    /// instance).
    pub fn take_storage(&mut self) -> Option<Box<dyn Storage>> {
        self.storage.take()
    }

    /// Append `rec` to the attached log, if any. A storage failure is
    /// fatal by design: a replica that cannot persist must stop
    /// executing and acking.
    fn persist(&mut self, rec: WalRecord) {
        if let Some(s) = self.storage.as_mut() {
            s.append(&rec).expect("replica wal append failed");
        }
    }

    /// Rewrite the durable record log to the retained chosen tail —
    /// watermark-driven truncation of the replica's WAL, mirroring the
    /// in-memory `log` truncation. Everything below the truncation
    /// floor is covered by the stored snapshot.
    fn compact_storage(&mut self) {
        if self.storage.is_none() {
            return;
        }
        let live: Vec<WalRecord> = self
            .log
            .iter()
            .map(|(&slot, v)| WalRecord::Chosen { slot, value: v.clone() })
            .collect();
        let s = self.storage.as_mut().unwrap();
        s.compact(&live).expect("replica wal compact failed");
    }

    /// Durably store a snapshot covering slots `< base`, then truncate
    /// the record log to the retained tail. The snapshot lands first:
    /// a crash between the two leaves a WAL that still covers
    /// everything the snapshot does (replay is idempotent), never a
    /// gap.
    fn store_snapshot(&mut self, base: Slot, bytes: &[u8]) {
        if self.storage.is_none() {
            return;
        }
        self.storage
            .as_mut()
            .unwrap()
            .put_snapshot(base, bytes)
            .expect("replica snapshot store failed");
        self.compact_storage();
    }

    /// Rebuild executed state after a crash: install the newest durable
    /// snapshot, re-insert the durable chosen tail, and re-execute it
    /// *quietly* — the state machine, dedup table, and watermarks all
    /// advance, but no client replies or leader acks are emitted (the
    /// pre-crash incarnation already sent them; recovery must not
    /// re-publish).
    pub fn recover(&mut self) {
        let (snap, recs) = {
            let Some(s) = self.storage.as_mut() else {
                return;
            };
            let snap = s.load_snapshot().expect("replica snapshot load failed");
            let recs = s.replay().expect("replica wal replay failed");
            (snap, recs)
        };
        if let Some((base, bytes)) = snap {
            assert!(
                self.install_snapshot(base, &bytes),
                "durable snapshot failed to install (corrupt store)"
            );
            // The recovered replica can serve snapshot catch-up again
            // right away.
            self.last_snapshot = Some((base, bytes));
        }
        for rec in recs {
            if let WalRecord::Chosen { slot, value } = rec {
                if slot >= self.truncated_below {
                    self.log.entry(slot).or_insert(value);
                }
            }
        }
        self.max_log_len = self.max_log_len.max(self.log.len());
        let mut quiet = Effects::new();
        self.execute_ready(self.id, &mut quiet);
        // Re-establish the durable live set (the snapshot install path
        // is storage-pure, so the tail on disk may predate it).
        self.compact_storage();
    }

    /// Execute every contiguous chosen slot, reply to clients, and ack the
    /// new prefix to the leader that informed us.
    fn execute_ready(&mut self, leader: NodeId, fx: &mut Effects) {
        let before = self.exec_watermark;
        loop {
            let Some(value) = self.log.get(&self.exec_watermark) else {
                break;
            };
            // Split borrows: the commands stay borrowed from the log
            // while the disjoint execution fields are mutated — no
            // per-slot clone on the execution hot path.
            match value {
                Value::Cmd(cmd) => exec_commands(
                    self.group,
                    self.exec_watermark,
                    std::slice::from_ref(cmd),
                    &mut self.client_table,
                    self.sm.as_mut(),
                    &mut self.executed,
                    fx,
                ),
                // Phase 2 batching: unpack and execute the whole batch
                // through one `StateMachine::apply_many` invocation,
                // replying to each client individually.
                Value::Batch(cmds) => exec_commands(
                    self.group,
                    self.exec_watermark,
                    cmds,
                    &mut self.client_table,
                    self.sm.as_mut(),
                    &mut self.executed,
                    fx,
                ),
                Value::Noop | Value::Reconfig(_) => {}
            }
            if self.announce_execs {
                fx.announce(Announce::Executed { slot: self.exec_watermark, replica: self.id });
            }
            self.exec_watermark += 1;
        }
        if self.exec_watermark != before {
            fx.send(leader, Msg::ReplicaAck { upto: self.exec_watermark });
            // The applied prefix advanced: resolved reads waiting on it
            // may now be servable.
            self.serve_ready_reads(fx);
        }
    }

    /// Length of the retained chosen log (metrics/tests).
    pub fn log_len(&self) -> usize {
        self.log.len()
    }

    /// Serialize the replica's executed state: the state-machine snapshot
    /// plus the client dedup/result table, prefixed with the execution
    /// watermark it covers. Everything a fresh replica needs to continue
    /// from `exec_watermark` with exactly-once semantics intact.
    fn encode_snapshot(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.exec_watermark);
        e.bytes(&self.sm.snapshot());
        #[allow(clippy::disallowed_methods)] // sorted immediately below
        let mut clients: Vec<(&NodeId, &ClientHistory)> = self.client_table.iter().collect();
        clients.sort_by_key(|(id, _)| **id);
        e.u32(clients.len() as u32);
        for (id, h) in clients {
            e.u32(*id);
            e.u64(h.highest);
            e.u32(h.recent.len() as u32);
            for (seq, (slot, result)) in &h.recent {
                e.u64(*seq);
                e.u64(*slot);
                e.bytes(result);
            }
        }
        e.buf
    }

    /// Install a peer snapshot covering slots `< base`. Refuses (and
    /// leaves local state untouched) when the bytes are malformed or the
    /// state machine rejects them. On success the replica continues
    /// executing from `base`.
    fn install_snapshot(&mut self, base: Slot, snap: &[u8]) -> bool {
        let mut d = Dec::new(snap);
        let Ok(watermark) = d.u64() else {
            return false;
        };
        if watermark != base {
            return false;
        }
        let Ok(sm_state) = d.bytes() else {
            return false;
        };
        let Ok(n) = d.u32() else {
            return false;
        };
        let mut table: HashMap<NodeId, ClientHistory> = HashMap::new();
        for _ in 0..n {
            let (Ok(client), Ok(highest), Ok(m)) = (d.u32(), d.u64(), d.u32()) else {
                return false;
            };
            let mut recent = BTreeMap::new();
            for _ in 0..m {
                let (Ok(seq), Ok(slot), Ok(result)) = (d.u64(), d.u64(), d.bytes()) else {
                    return false;
                };
                recent.insert(seq, (slot, result));
            }
            table.insert(client, ClientHistory { highest, recent });
        }
        if !d.done() || !self.sm.restore(&sm_state) {
            return false;
        }
        self.client_table = table;
        self.exec_watermark = base;
        self.truncated_below = base;
        self.log = self.log.split_off(&base);
        true
    }

    /// Periodic snapshot tick: capture the state, truncate the chosen log
    /// below `watermark - tail`, and retire result-cache entries below the
    /// truncation floor (the watermark bound on the retry cache — the
    /// count bound alone lets idle clients' entries linger forever).
    ///
    /// The tail is thereby also the retry horizon: a retry arriving more
    /// than `tail` slots after its command executed finds no cached
    /// result and is treated as settled (silence, never re-execution —
    /// the dedup cursor survives). Deployments on lossy networks should
    /// size `tail` to cover the client resend timeout at the expected
    /// slot rate.
    fn on_snapshot_tick(&mut self, _now: Time, fx: &mut Effects) {
        if !self.snapshot.enabled {
            return;
        }
        let upto = self.exec_watermark;
        if upto > self.last_snapshot.as_ref().map_or(0, |(s, _)| *s) {
            let bytes = self.encode_snapshot();
            // Durable store first: the WAL truncation below must never
            // outrun the snapshot that covers what it drops.
            if self.storage.is_some() {
                self.storage
                    .as_mut()
                    .unwrap()
                    .put_snapshot(upto, &bytes)
                    .expect("replica snapshot store failed");
            }
            self.last_snapshot = Some((upto, bytes));
            self.snapshots_taken += 1;
            fx.announce(Announce::SnapshotTaken { replica: self.id, upto });
            let floor = upto.saturating_sub(self.snapshot.tail);
            if floor > self.truncated_below {
                self.truncated_below = floor;
                self.log = self.log.split_off(&floor);
                // Per-entry mutation, independent of visitation order.
                #[allow(clippy::disallowed_methods)]
                for h in self.client_table.values_mut() {
                    h.recent.retain(|_, v| v.0 >= floor);
                }
                fx.announce(Announce::ReplicaTruncated {
                    replica: self.id,
                    below: floor,
                    exec: self.exec_watermark,
                });
            }
            self.compact_storage();
        }
        fx.timer(self.snapshot.interval, Timer::SnapshotTick);
    }

    /// Whether this replica holds an unexpired lease grant at `now`
    /// (tests/metrics; the grant's validity is already drift-discounted
    /// by the leader).
    pub fn lease_active(&self, now: Time) -> bool {
        matches!(self.lease, Some((_, _, valid_until)) if valid_until > now)
    }

    /// Pending linearizable reads (tests/metrics).
    pub fn pending_read_count(&self) -> usize {
        self.pending_reads.len()
    }

    /// Where a ReadIndex request should go: the observed leader, else
    /// the rotating proposer hint.
    fn read_index_target(&self) -> Option<NodeId> {
        if let Some(l) = self.last_leader {
            return Some(l);
        }
        if self.proposers.is_empty() {
            None
        } else {
            Some(self.proposers[self.leader_hint % self.proposers.len()])
        }
    }

    /// Send a ReadIndex request if none is outstanding.
    fn ensure_read_index(&mut self, now: Time, fx: &mut Effects) {
        if self.read_req_inflight.is_some() {
            return;
        }
        let Some(target) = self.read_index_target() else {
            return;
        };
        self.read_req_next += 1;
        self.read_req_inflight = Some((self.read_req_next, now));
        fx.send(target, Msg::ReadIndexReq { id: self.read_req_next });
    }

    fn arm_read_timer(&mut self, fx: &mut Effects) {
        if !self.read_timer_armed {
            self.read_timer_armed = true;
            fx.timer(READ_RETRY, Timer::ReadIndexRetry);
        }
    }

    /// Answer every resolved read whose read index the applied prefix
    /// now covers. The comparison is against `exec_watermark` — the
    /// *post-restore applied index* — never the raw chosen-log length,
    /// so a snapshot-truncated replica that caught up via state
    /// transfer serves correctly even though its log holds only the
    /// retained tail.
    fn serve_ready_reads(&mut self, fx: &mut Effects) {
        if self.pending_reads.is_empty() {
            return;
        }
        let mut i = 0;
        while i < self.pending_reads.len() {
            let ready = match self.pending_reads[i].state {
                ReadState::Ready(w) => w <= self.exec_watermark,
                _ => false,
            };
            if ready {
                let pr = self.pending_reads.remove(i).expect("index in bounds");
                let result = self.sm.query(&pr.payload);
                fx.send(
                    pr.client,
                    Msg::ReadReply { group: self.group, seq: pr.seq, result },
                );
            } else {
                i += 1;
            }
        }
    }

    /// A linearizable read arrived from a client. Under an active lease
    /// it waits for the next grant (issued after arrival) to learn a
    /// covering watermark for free; otherwise it takes the one-message
    /// ReadIndex path; with no possible leader target it redirects the
    /// client to try another replica.
    fn on_read(&mut self, from: NodeId, seq: u64, payload: Vec<u8>, now: Time, fx: &mut Effects) {
        if self.pending_reads.len() >= MAX_PENDING_READS {
            return; // shed; the client's resend recovers
        }
        let state = if self.lease_active(now) {
            ReadState::AwaitGrant
        } else if self.read_index_target().is_some() {
            self.ensure_read_index(now, fx);
            ReadState::AwaitIndex
        } else {
            fx.send(from, Msg::NotLeaseholder { group: self.group, hint: None });
            return;
        };
        self.pending_reads.push_back(PendingRead {
            client: from,
            seq,
            payload,
            arrived_at: now,
            state,
        });
        self.arm_read_timer(fx);
    }

    /// The next catch-up peer after `cur`: rotate through the peer list
    /// (excluding ourselves) so retries don't hammer a dead node forever.
    fn next_peer(&self, cur: NodeId) -> NodeId {
        let candidates: Vec<NodeId> =
            self.peers.iter().copied().filter(|&p| p != self.id).collect();
        if candidates.is_empty() {
            return cur;
        }
        match candidates.iter().position(|&p| p == cur) {
            Some(i) => candidates[(i + 1) % candidates.len()],
            None => candidates[0],
        }
    }

    /// Stream `state` (covering slots `< base`) to `to` as ordered
    /// [`Msg::SnapshotChunk`]s, starting at chunk `from_seq` — 0 for a
    /// fresh transfer, the receiver's cursor for a resume. The sender
    /// keeps no per-receiver state: a resume re-chunks the cached
    /// snapshot bytes, which is what makes resumption after a
    /// *receiver* restart possible at all.
    fn send_chunks(&self, to: NodeId, base: Slot, state: &[u8], from_seq: u32, fx: &mut Effects) {
        let size = self.chunk_bytes.max(1);
        if state.is_empty() {
            // Degenerate but legal: one empty chunk keeps the receiver
            // protocol uniform.
            if from_seq == 0 {
                fx.send(to, Msg::SnapshotChunk { base, seq: 0, total: 1, bytes: Vec::new() });
            }
            return;
        }
        let total = state.chunks(size).len() as u32;
        for (seq, chunk) in state.chunks(size).enumerate() {
            let seq = seq as u32;
            if seq < from_seq {
                continue;
            }
            fx.send(to, Msg::SnapshotChunk { base, seq, total, bytes: chunk.to_vec() });
        }
    }

    /// Serve snapshot-plus-tail catch-up to `to`, whose applied prefix
    /// is `req_from`. When the retained log alone covers the gap, a
    /// single entries-only `SnapshotResp` suffices; otherwise the state
    /// snapshot is streamed as ordered `SnapshotChunk`s and the
    /// requester fetches the entries tail with a follow-up
    /// `SnapshotRequest` once it installs the assembled state.
    fn serve_snapshot_request(&mut self, to: NodeId, req_from: Slot, fx: &mut Effects) {
        if req_from >= self.truncated_below {
            let entries: Vec<(Slot, Value)> = if req_from < self.exec_watermark {
                self.log
                    .range(req_from..self.exec_watermark)
                    .map(|(s, v)| (*s, v.clone()))
                    .collect()
            } else {
                Vec::new()
            };
            fx.send(to, Msg::SnapshotResp { base: req_from, state: Vec::new(), entries });
            return;
        }
        // The stored snapshot must also cover our own truncation floor
        // (it can briefly lag right after we installed a peer snapshot
        // ourselves) or the tail would have gaps.
        let (base, state) = match &self.last_snapshot {
            Some((s, bytes)) if *s > req_from && *s >= self.truncated_below => {
                (*s, bytes.clone())
            }
            _ => {
                let state = self.encode_snapshot();
                // Cache it: a mid-transfer `SnapshotResume` for this
                // base must be able to re-chunk the identical bytes.
                self.last_snapshot = Some((self.exec_watermark, state.clone()));
                (self.exec_watermark, state)
            }
        };
        self.send_chunks(to, base, &state, 0, fx);
    }
}

/// Execute a run of commands from one slot: deduplicate retries
/// (re-replying with the cached result), then apply the fresh suffix as a
/// single state-machine batch, in order, with one reply per command.
///
/// A free function over the replica's disjoint execution fields so the
/// commands can stay borrowed from the log (no clone per executed slot).
fn exec_commands(
    group: GroupId,
    slot: Slot,
    cmds: &[Command],
    client_table: &mut HashMap<NodeId, ClientHistory>,
    sm: &mut dyn StateMachine,
    executed: &mut u64,
    fx: &mut Effects,
) {
    let mut fresh: Vec<&Command> = Vec::with_capacity(cmds.len());
    for cmd in cmds {
        let dup = client_table
            .get(&cmd.client)
            .map_or(false, |h| h.highest >= cmd.seq);
        if dup {
            // Re-chosen retry of an executed command: re-reply with the
            // cached result, do not re-execute.
            if let Some((_, result)) = client_table
                .get(&cmd.client)
                .and_then(|h| h.recent.get(&cmd.seq))
            {
                fx.send(
                    cmd.client,
                    Msg::ClientReply { group, seq: cmd.seq, result: result.clone() },
                );
            }
        } else {
            fresh.push(cmd);
        }
    }
    if fresh.is_empty() {
        return;
    }
    let payloads: Vec<&[u8]> = fresh.iter().map(|c| c.payload.as_slice()).collect();
    let results = sm.apply_many(&payloads);
    debug_assert_eq!(results.len(), fresh.len());
    for (cmd, result) in fresh.iter().zip(results) {
        *executed += 1;
        let h = client_table.entry(cmd.client).or_default();
        h.highest = h.highest.max(cmd.seq);
        h.recent.insert(cmd.seq, (slot, result.clone()));
        while h.recent.len() > RESULT_CACHE {
            let oldest = *h.recent.keys().next().unwrap();
            h.recent.remove(&oldest);
        }
        fx.send(cmd.client, Msg::ClientReply { group, seq: cmd.seq, result });
    }
}

impl Node for Replica {
    fn on_start(&mut self, _now: Time, fx: &mut Effects) {
        if self.snapshot.enabled {
            fx.timer(self.snapshot.interval, Timer::SnapshotTick);
        }
    }

    fn on_msg(&mut self, now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::Chosen { slot, value } => {
                // The sender is the live leader: remember it as the
                // ReadIndex target.
                self.last_leader = Some(from);
                // Idempotent insert: chosen values never conflict (safety),
                // so a duplicate insert is a no-op. Slots below the
                // truncation floor are already covered by the snapshot.
                // Fresh entries hit the durable log *before* they can
                // influence the `ReplicaAck` below (fsync-before-ack:
                // the leader GC-truncates on the strength of our acks).
                if slot >= self.truncated_below {
                    if !self.log.contains_key(&slot) {
                        if self.storage.is_some() {
                            self.persist(WalRecord::Chosen { slot, value: value.clone() });
                        }
                        self.log.insert(slot, value);
                    }
                    self.max_log_len = self.max_log_len.max(self.log.len());
                }
                let before = self.exec_watermark;
                self.execute_ready(from, fx);
                if self.exec_watermark == before && slot > self.exec_watermark {
                    // We have a hole: ack our (unchanged) watermark so the
                    // leader can re-send the missing entries (or point us
                    // at a peer snapshot if it truncated them).
                    fx.send(from, Msg::ReplicaAck { upto: self.exec_watermark });
                }
            }
            // A (new) leader asks for the chosen prefix (§4.1). The
            // requested start may exceed our watermark (the leader already
            // knows more than us): clamp the range. Truncated slots are
            // absent — a lagging peer recovers them via snapshot
            // catch-up, not entry-by-entry.
            Msg::ReadPrefix { from: from_slot } => {
                let start = from_slot.min(self.exec_watermark);
                let entries: Vec<(Slot, Value)> = self
                    .log
                    .range(start..self.exec_watermark)
                    .map(|(s, v)| (*s, v.clone()))
                    .collect();
                fx.send(from, Msg::PrefixResp { entries, upto: self.exec_watermark });
            }
            // The leader truncated the prefix we are missing: fetch a
            // snapshot from the peer it named. A retry timer re-issues
            // the request if the response is lost.
            Msg::CatchUp { below, peer } => {
                if self.exec_watermark >= below || peer == self.id {
                    return;
                }
                let due = match self.catchup {
                    Some((_, _, t)) => now.saturating_sub(t) >= CATCHUP_RETRY,
                    None => true,
                };
                if due {
                    // One retry chain at a time: the timer keeps itself
                    // armed while `catchup` is set.
                    if !self.catchup_timer_armed {
                        self.catchup_timer_armed = true;
                        fx.timer(CATCHUP_RETRY, Timer::CatchupRetry);
                    }
                    self.catchup = Some((peer, below, now));
                    fx.send(peer, Msg::SnapshotRequest { from: self.exec_watermark });
                } else if let Some(c) = &mut self.catchup {
                    // Track the newest target for the pending retry. The
                    // peer is NOT overwritten: retry rotation may have
                    // moved past a dead hinted peer on purpose.
                    c.1 = c.1.max(below);
                }
            }
            // Serve snapshot-plus-tail catch-up. When the retained log
            // alone covers the requester's gap, skip the state transfer
            // entirely and ship just the entries; otherwise stream the
            // stored periodic snapshot (or a fresh one at the current
            // watermark) as ordered chunks.
            Msg::SnapshotRequest { from: req_from } => {
                self.serve_snapshot_request(from, req_from, fx);
            }
            // A mid-transfer receiver asking us to re-send from its
            // cursor. If we still hold the snapshot it was receiving,
            // resume exactly there; otherwise (we restarted, or a newer
            // snapshot replaced it) restart the transfer from our
            // current best — the receiver discards chunks for the
            // now-stale base and assembles the new one.
            Msg::SnapshotResume { base, next } => {
                let resumable = matches!(&self.last_snapshot, Some((s, _)) if *s == base);
                if resumable {
                    let (_, bytes) = self.last_snapshot.as_ref().expect("checked above");
                    self.send_chunks(from, base, bytes, next, fx);
                } else {
                    self.serve_snapshot_request(from, 0, fx);
                }
            }
            // One chunk of a peer's snapshot stream. Strictly in-order
            // assembly: a gap parks the transfer until the retry tick
            // sends a `SnapshotResume` from the cursor.
            Msg::SnapshotChunk { base, seq, total, bytes } => {
                if base <= self.exec_watermark || total == 0 {
                    return; // stale transfer (or nonsense): already past it
                }
                let fresh_needed = match &self.assembly {
                    Some(a) => a.peer != from || a.base != base || a.total != total,
                    None => true,
                };
                if fresh_needed {
                    if seq != 0 {
                        // Mid-stream chunk of a transfer we are not
                        // assembling (we restarted, or abandoned it):
                        // ask for the prefix we are missing.
                        fx.send(from, Msg::SnapshotResume { base, next: 0 });
                        return;
                    }
                    self.assembly = Some(ChunkAssembly {
                        peer: from,
                        base,
                        total,
                        next_seq: 0,
                        buf: Vec::new(),
                        stalls: 0,
                        seq_at_last_tick: 0,
                    });
                }
                let a = self.assembly.as_mut().expect("assembly ensured above");
                if seq != a.next_seq {
                    return; // duplicate or gap; the retry tick resumes
                }
                a.buf.extend_from_slice(&bytes);
                a.next_seq += 1;
                // Streaming counts as catch-up progress (quiets the
                // rotating-request retry path while chunks flow).
                if let Some(c) = &mut self.catchup {
                    c.2 = now;
                }
                if a.next_seq < a.total {
                    // Stall insurance even when no leader CatchUp armed
                    // the chain (e.g. an unsolicited restarted transfer).
                    if !self.catchup_timer_armed {
                        self.catchup_timer_armed = true;
                        fx.timer(CATCHUP_RETRY, Timer::CatchupRetry);
                    }
                    return;
                }
                let ChunkAssembly { base, buf, .. } =
                    self.assembly.take().expect("assembly complete");
                if !self.install_snapshot(base, &buf) {
                    return; // malformed: the retry path re-requests
                }
                self.store_snapshot(base, &buf);
                self.snapshots_installed += 1;
                fx.announce(Announce::SnapshotInstalled { replica: self.id, base });
                // The applied prefix jumped to `base`: resolved reads
                // waiting on it may now be servable.
                self.serve_ready_reads(fx);
                // Fetch the chosen tail above the base (entries-only
                // path on the sender, since `base >= truncated_below`
                // there).
                fx.send(from, Msg::SnapshotRequest { from: self.exec_watermark });
                if let Some(c) = &mut self.catchup {
                    c.2 = now;
                }
            }
            Msg::SnapshotResp { base, state, entries } => {
                let before = self.exec_watermark;
                if base > self.exec_watermark {
                    if !self.install_snapshot(base, &state) {
                        return;
                    }
                    self.store_snapshot(base, &state);
                    self.snapshots_installed += 1;
                    fx.announce(Announce::SnapshotInstalled { replica: self.id, base });
                }
                for (slot, value) in entries {
                    if slot >= self.truncated_below && !self.log.contains_key(&slot) {
                        if self.storage.is_some() {
                            self.persist(WalRecord::Chosen { slot, value: value.clone() });
                        }
                        self.log.insert(slot, value);
                    }
                }
                self.max_log_len = self.max_log_len.max(self.log.len());
                // Execute the tail; the ack goes to the serving peer
                // (which ignores it) — the leader learns our new
                // watermark from the ack on its next Chosen.
                self.execute_ready(from, fx);
                match self.catchup {
                    Some((_, below, _)) if self.exec_watermark >= below => {
                        self.catchup = None;
                    }
                    Some((peer, below, _)) if self.exec_watermark > before => {
                        // Progress but not at the target yet (the peer may
                        // have truncated past us again): request the next
                        // increment right away.
                        self.catchup = Some((peer, below, now));
                        fx.send(peer, Msg::SnapshotRequest { from: self.exec_watermark });
                    }
                    // No progress: leave the retry timer to re-request at
                    // a bounded rate instead of ping-ponging per RTT.
                    _ => {}
                }
            }
            // ---- Linearizable reads (DESIGN.md §Reads) ----
            Msg::Read { group, seq, payload } => {
                // Static routing: a read for another group means a
                // broken router.
                debug_assert_eq!(group, self.group, "read routed to wrong group");
                if group != self.group {
                    return;
                }
                self.on_read(from, seq, payload, now, fx);
            }
            Msg::LeaseGrant { round: _, upto, granted_at, valid_until } => {
                self.last_leader = Some(from);
                // Adopt the newest grant (by issue time).
                let newer = self
                    .lease
                    .map_or(true, |(_, g, _)| granted_at >= g);
                if newer {
                    self.lease = Some((upto, granted_at, valid_until));
                }
                // A grant issued at `granted_at` carries a watermark
                // covering every write acknowledged anywhere before it:
                // reads that arrived earlier resolve against it.
                for pr in self.pending_reads.iter_mut() {
                    if pr.state == ReadState::AwaitGrant && pr.arrived_at <= granted_at {
                        pr.state = ReadState::Ready(upto);
                        self.reads_leased += 1;
                    }
                }
                self.serve_ready_reads(fx);
            }
            Msg::ReadIndexResp { id, upto } => {
                let Some((cur, sent_at)) = self.read_req_inflight else {
                    return;
                };
                if cur != id {
                    return; // stale response (we moved on)
                }
                self.read_req_inflight = None;
                // The response covers reads that arrived before the
                // request was sent; later arrivals need a fresh request.
                let mut uncovered = false;
                for pr in self.pending_reads.iter_mut() {
                    if pr.state == ReadState::AwaitIndex {
                        if pr.arrived_at <= sent_at {
                            pr.state = ReadState::Ready(upto);
                            self.reads_indexed += 1;
                        } else {
                            uncovered = true;
                        }
                    }
                }
                self.serve_ready_reads(fx);
                if uncovered {
                    self.ensure_read_index(now, fx);
                }
            }
            Msg::NotLeader { group, hint } => {
                // Our ReadIndex request hit a follower: retarget and
                // re-ask under a fresh request id (a late answer from
                // the old id is ignored).
                if group != self.group {
                    return;
                }
                self.last_leader = hint;
                if hint.is_none() && !self.proposers.is_empty() {
                    self.leader_hint = (self.leader_hint + 1) % self.proposers.len();
                }
                if self.read_req_inflight.take().is_some() {
                    self.ensure_read_index(now, fx);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, now: Time, timer: Timer, fx: &mut Effects) {
        match timer {
            Timer::SnapshotTick => self.on_snapshot_tick(now, fx),
            Timer::ReadIndexRetry => {
                self.read_timer_armed = false;
                if self.pending_reads.is_empty() {
                    return;
                }
                // Expire abandoned reads (FIFO by arrival, so the front
                // is always the oldest).
                while let Some(front) = self.pending_reads.front() {
                    if now.saturating_sub(front.arrived_at) >= READ_EXPIRE {
                        self.pending_reads.pop_front();
                    } else {
                        break;
                    }
                }
                // Lease-expiry fallback: grant-waiting reads past the
                // patience window switch to the ReadIndex path (the
                // lease lapsed, or grants paused for an installation).
                let mut need_index = false;
                for pr in self.pending_reads.iter_mut() {
                    if pr.state == ReadState::AwaitGrant
                        && now.saturating_sub(pr.arrived_at) >= READ_GRANT_PATIENCE
                    {
                        pr.state = ReadState::AwaitIndex;
                    }
                    if pr.state == ReadState::AwaitIndex {
                        need_index = true;
                    }
                }
                // A request unanswered for a full retry window is lost
                // or its target is down/deposed: rotate and re-ask.
                if let Some((_, sent)) = self.read_req_inflight {
                    if now.saturating_sub(sent) >= READ_RETRY {
                        self.read_req_inflight = None;
                        self.last_leader = None;
                        if !self.proposers.is_empty() {
                            self.leader_hint = (self.leader_hint + 1) % self.proposers.len();
                        }
                    }
                }
                if need_index {
                    self.ensure_read_index(now, fx);
                }
                if !self.pending_reads.is_empty() {
                    self.arm_read_timer(fx);
                }
            }
            Timer::CatchupRetry => {
                self.catchup_timer_armed = false;
                // Drop state that caught up some other way.
                if self.assembly.as_ref().map_or(false, |a| a.base <= self.exec_watermark) {
                    self.assembly = None;
                }
                if let Some((_, below, _)) = self.catchup {
                    if self.exec_watermark >= below {
                        self.catchup = None;
                    }
                }
                // An in-flight chunk assembly owns the retry slot: while
                // the stream flows nothing is sent; on the first silent
                // ticks the transfer resumes from the cursor; after
                // MAX_RESUME_STALLS silent ticks the sender is presumed
                // dead and catch-up falls back to peer rotation below.
                let mut rotate = self.catchup.is_some();
                if let Some(a) = &mut self.assembly {
                    if a.next_seq > a.seq_at_last_tick {
                        a.seq_at_last_tick = a.next_seq;
                        a.stalls = 0;
                        rotate = false;
                    } else {
                        a.stalls += 1;
                        if a.stalls < MAX_RESUME_STALLS {
                            let (peer, base, next) = (a.peer, a.base, a.next_seq);
                            fx.send(peer, Msg::SnapshotResume { base, next });
                            rotate = false;
                        } else {
                            self.assembly = None;
                        }
                    }
                }
                if rotate {
                    if let Some((peer, below, last)) = self.catchup {
                        if now.saturating_sub(last) >= CATCHUP_RETRY {
                            // No response within the window: the peer may
                            // be slow, the message lost, or the peer dead
                            // — rotate.
                            let peer = self.next_peer(peer);
                            self.catchup = Some((peer, below, now));
                            fx.send(peer, Msg::SnapshotRequest { from: self.exec_watermark });
                        }
                    }
                }
                if self.catchup.is_some() || self.assembly.is_some() {
                    self.catchup_timer_armed = true;
                    fx.timer(CATCHUP_RETRY, Timer::CatchupRetry);
                }
            }
            _ => {}
        }
    }

    fn role(&self) -> &'static str {
        "replica"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn state_repr(&self) -> Option<String> {
        use std::fmt::Write;
        let mut s = format!(
            "rep g={} log={:?} exec={} trunc={} sm={:?} snap={:?} lease={:?}",
            self.group,
            self.log,
            self.exec_watermark,
            self.truncated_below,
            self.sm.snapshot(),
            self.last_snapshot.as_ref().map(|(w, _)| *w),
            self.lease,
        );
        // client_table is a HashMap: render in sorted order so equal
        // states hash equally.
        #[allow(clippy::disallowed_methods)] // sorted immediately below
        let mut clients: Vec<(&NodeId, &ClientHistory)> = self.client_table.iter().collect();
        clients.sort_by_key(|(id, _)| **id);
        for (id, h) in clients {
            let _ = write!(s, " c{}={{{},{:?}}}", id, h.highest, h.recent);
        }
        // Pending reads matter for future behavior; their arrival times
        // do not (the repr must stay time-free where possible, and the
        // expiry paths are driven by excluded retry timers anyway).
        for p in &self.pending_reads {
            let _ = write!(s, " pr={}/{}:{:?}", p.client, p.seq, p.state);
        }
        if let Some((peer, target, _)) = &self.catchup {
            let _ = write!(s, " cu={peer}->{target}");
        }
        // The attached durable store is deliberately excluded: it is a
        // mirror of this state, not additional state.
        if let Some(a) = &self.assembly {
            let _ = write!(s, " asm={}@{}:{}/{}", a.peer, a.base, a.next_seq, a.total);
        }
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Command;
    use crate::statemachine::{KvStore, Noop};

    fn cmd(client: NodeId, seq: u64, payload: &[u8]) -> Value {
        Value::Cmd(Command { client, seq, payload: payload.to_vec() })
    }

    fn deliver(r: &mut Replica, from: NodeId, m: Msg) -> Effects {
        let mut fx = Effects::new();
        r.on_msg(0, from, m, &mut fx);
        fx
    }

    #[test]
    fn executes_in_prefix_order() {
        let mut r = Replica::new(1, Box::new(Noop));
        // Slot 1 arrives first: no execution (hole at 0).
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 1, value: cmd(9, 0, b"b") });
        assert_eq!(r.exec_watermark, 0);
        assert!(fx.msgs.iter().all(|(_, m)| !matches!(m, Msg::ClientReply { .. })));
        // Slot 0 arrives: both execute, in order.
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(8, 0, b"a") });
        assert_eq!(r.exec_watermark, 2);
        let replies: Vec<&NodeId> = fx
            .msgs
            .iter()
            .filter(|(_, m)| matches!(m, Msg::ClientReply { .. }))
            .map(|(to, _)| to)
            .collect();
        assert_eq!(replies, vec![&8, &9]);
        // Acked the new prefix to the leader.
        assert!(fx.msgs.contains(&(0, Msg::ReplicaAck { upto: 2 })));
    }

    #[test]
    fn noop_advances_without_reply() {
        let mut r = Replica::new(1, Box::new(Noop));
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 0, value: Value::Noop });
        assert_eq!(r.exec_watermark, 1);
        assert!(fx.msgs.iter().all(|(_, m)| !matches!(m, Msg::ClientReply { .. })));
    }

    #[test]
    fn duplicate_command_not_reexecuted() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        // set k=1
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 0, b"skv") });
        assert_eq!(r.executed, 1);
        // Same (client, seq) re-chosen at a later slot (leader retry path):
        // executed once only, but the client still gets a reply.
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 1, value: cmd(7, 0, b"skv") });
        assert_eq!(r.executed, 1);
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 7 && matches!(m, Msg::ClientReply { seq: 0, .. })));
    }

    #[test]
    fn read_prefix() {
        let mut r = Replica::new(1, Box::new(Noop));
        for s in 0..4 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: Value::Noop });
        }
        let fx = deliver(&mut r, 5, Msg::ReadPrefix { from: 1 });
        match &fx.msgs[0].1 {
            Msg::PrefixResp { entries, upto } => {
                assert_eq!(*upto, 4);
                assert_eq!(entries.len(), 3);
                assert_eq!(entries[0].0, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_executes_in_order_with_per_command_replies() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        let batch = Value::Batch(vec![
            Command { client: 7, seq: 1, payload: KvStore::enc_set(b"k", b"v1") },
            Command { client: 8, seq: 1, payload: KvStore::enc_get(b"k") },
            Command { client: 7, seq: 2, payload: KvStore::enc_set(b"k", b"v2") },
        ]);
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 0, value: batch });
        assert_eq!(r.exec_watermark, 1);
        assert_eq!(r.executed, 3);
        // Per-command replies, in batch order: client 8's get observes
        // client 7's earlier set (FIFO within the batch).
        let replies: Vec<(NodeId, u64, Vec<u8>)> = fx
            .msgs
            .iter()
            .filter_map(|(to, m)| match m {
                Msg::ClientReply { seq, result, .. } => Some((*to, *seq, result.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(replies.len(), 3);
        assert_eq!(replies[0], (7, 1, b"OK".to_vec()));
        assert_eq!(replies[1], (8, 1, b"v1".to_vec()));
        assert_eq!(replies[2], (7, 2, b"OK".to_vec()));
        // One ack for the new prefix.
        assert!(fx.msgs.contains(&(0, Msg::ReplicaAck { upto: 1 })));
    }

    #[test]
    fn rechosen_batch_not_reexecuted() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        let batch = Value::Batch(vec![
            Command { client: 7, seq: 1, payload: KvStore::enc_set(b"k", b"v1") },
            Command { client: 8, seq: 1, payload: KvStore::enc_set(b"j", b"w") },
        ]);
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: batch.clone() });
        assert_eq!(r.executed, 2);
        // The same batch re-chosen at a later slot (leader retry across a
        // reconfiguration): exactly-once execution, but both clients get
        // their cached replies again.
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 1, value: batch });
        assert_eq!(r.executed, 2);
        let replies = fx
            .msgs
            .iter()
            .filter(|(_, m)| matches!(m, Msg::ClientReply { .. }))
            .count();
        assert_eq!(replies, 2);
    }

    #[test]
    fn retry_of_older_pipelined_seq_gets_cached_reply() {
        // A pipelined client lost the reply to seq 1 while seq 2 already
        // executed: the retry (re-chosen at a later slot) must still get
        // seq 1's cached result, not silence.
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 1, b"skv") });
        deliver(&mut r, 0, Msg::Chosen { slot: 1, value: cmd(7, 2, b"gk") });
        assert_eq!(r.executed, 2);
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 2, value: cmd(7, 1, b"skv") });
        assert_eq!(r.executed, 2, "retry must not re-execute");
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 7 && matches!(m, Msg::ClientReply { seq: 1, .. })));
    }

    #[test]
    fn result_cache_is_bounded() {
        let mut r = Replica::new(1, Box::new(Noop));
        for s in 0..(RESULT_CACHE as u64 + 50) {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"x") });
        }
        let h = r.client_table.get(&7).unwrap();
        assert_eq!(h.recent.len(), RESULT_CACHE);
        assert_eq!(h.highest, RESULT_CACHE as u64 + 50);
        // Oldest entries were evicted.
        assert!(!h.recent.contains_key(&1));
        assert!(h.recent.contains_key(&(RESULT_CACHE as u64 + 50)));
    }

    #[test]
    fn chosen_is_idempotent() {
        let mut r = Replica::new(1, Box::new(Noop));
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 0, b"x") });
        let executed = r.executed;
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 0, b"x") });
        assert_eq!(r.executed, executed);
        assert_eq!(r.exec_watermark, 1);
    }

    // ---- State retention ----

    fn snapshotting_replica(tail: u64) -> Replica {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        // Bypass the `every()` clamp deliberately: unit tests want tiny
        // tails to keep slot counts small.
        r.snapshot = SnapshotSpec { enabled: true, interval: MS, tail };
        r.peers = vec![1, 2, 3];
        r
    }

    fn tick(r: &mut Replica, now: Time) -> Effects {
        let mut fx = Effects::new();
        r.on_timer(now, Timer::SnapshotTick, &mut fx);
        fx
    }

    #[test]
    fn snapshot_tick_truncates_log_and_rearms() {
        let mut r = snapshotting_replica(4);
        for s in 0..10 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        assert_eq!(r.log_len(), 10);
        let fx = tick(&mut r, MS);
        // Snapshot at watermark 10; log keeps the 4-entry tail [6, 10).
        assert_eq!(r.snapshots_taken, 1);
        assert_eq!(r.truncated_below, 6);
        assert_eq!(r.log_len(), 4);
        assert!(r.log.contains_key(&6) && !r.log.contains_key(&5));
        assert!(fx.timers.iter().any(|(_, t)| *t == Timer::SnapshotTick));
        assert!(fx
            .announces
            .iter()
            .any(|a| matches!(a, Announce::SnapshotTaken { upto: 10, .. })));
        // Idle tick: no new snapshot, but the timer re-arms.
        let fx = tick(&mut r, 2 * MS);
        assert_eq!(r.snapshots_taken, 1);
        assert!(fx.timers.iter().any(|(_, t)| *t == Timer::SnapshotTick));
        // Chosen below the truncation floor is ignored (covered by the
        // snapshot), and the max-log high-water mark saw the peak.
        deliver(&mut r, 0, Msg::Chosen { slot: 2, value: cmd(7, 3, b"skv") });
        assert_eq!(r.log_len(), 4);
        assert_eq!(r.max_log_len, 10);
    }

    #[test]
    fn truncation_bounds_result_cache_by_watermark() {
        let mut r = snapshotting_replica(4);
        for s in 0..10 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        assert_eq!(r.client_table[&7].recent.len(), 10);
        tick(&mut r, MS);
        // Results for slots below the floor (6) are retired; the dedup
        // cursor survives.
        let h = &r.client_table[&7];
        assert_eq!(h.recent.len(), 4);
        assert_eq!(h.highest, 10);
        assert!(h.recent.keys().all(|&seq| seq >= 7));
    }

    #[test]
    fn snapshot_transfer_catches_up_fresh_replica() {
        // Peer executes 20 kv commands, snapshots, truncates.
        let mut peer = snapshotting_replica(4);
        for s in 0..20 {
            deliver(&mut peer, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        tick(&mut peer, MS);
        assert_eq!(peer.truncated_below, 16);

        // A fresh replica is pointed at the peer by the leader.
        let mut fresh = snapshotting_replica(4);
        fresh.id = 2;
        let mut fx = Effects::new();
        fresh.on_msg(10 * MS, 0, Msg::CatchUp { below: 16, peer: 1 }, &mut fx);
        let req = fx.msgs.iter().find(|(to, m)| {
            *to == 1 && matches!(m, Msg::SnapshotRequest { from: 0 })
        });
        assert!(req.is_some(), "{:?}", fx.msgs);
        // ... and arms a retry timer (a lost response must recover even
        // with no further traffic to trigger another CatchUp hint).
        assert!(fx.timers.iter().any(|(_, t)| *t == Timer::CatchupRetry));
        // Within the retry window, a second CatchUp is a no-op.
        let mut fx2 = Effects::new();
        fresh.on_msg(10 * MS + 1, 0, Msg::CatchUp { below: 16, peer: 1 }, &mut fx2);
        assert!(fx2.msgs.is_empty());
        // The retry timer re-issues the request once the window passes.
        let mut fxt = Effects::new();
        fresh.on_timer(10 * MS + CATCHUP_RETRY, Timer::CatchupRetry, &mut fxt);
        assert_eq!(fxt.msgs.len(), 1, "{:?}", fxt.msgs);
        assert!(fxt.timers.iter().any(|(_, t)| *t == Timer::CatchupRetry));
        // A further CatchUp after the window also re-requests.
        let mut fx3 = Effects::new();
        fresh.on_msg(10 * MS + 2 * CATCHUP_RETRY, 0, Msg::CatchUp { below: 16, peer: 1 }, &mut fx3);
        assert_eq!(fx3.msgs.len(), 1);

        // The peer streams its stored snapshot as chunks; the fresh
        // replica assembles and installs it, then fetches the entries
        // tail, converging without re-executing.
        let resp = deliver(&mut peer, 2, Msg::SnapshotRequest { from: 0 });
        let (base, seq, total, bytes) = match &resp.msgs[0] {
            (2, Msg::SnapshotChunk { base, seq, total, bytes }) => {
                (*base, *seq, *total, bytes.clone())
            }
            other => panic!("{other:?}"),
        };
        assert_eq!(base, 20, "stored snapshot covers the full executed prefix");
        assert_eq!((seq, total), (0, 1), "small state fits one chunk");
        let fx4 = deliver(&mut fresh, 1, Msg::SnapshotChunk { base, seq, total, bytes });
        assert_eq!(fresh.exec_watermark, 20);
        assert_eq!(fresh.snapshots_installed, 1);
        // The install triggers the entries-tail fetch; the peer answers
        // entries-only (nothing above the base yet).
        assert!(
            fx4.msgs
                .iter()
                .any(|(to, m)| *to == 1 && matches!(m, Msg::SnapshotRequest { from: 20 })),
            "{:?}",
            fx4.msgs
        );
        let tail = deliver(&mut peer, 2, Msg::SnapshotRequest { from: 20 });
        match &tail.msgs[0].1 {
            Msg::SnapshotResp { base: 20, state, entries } => {
                assert!(state.is_empty() && entries.is_empty());
            }
            other => panic!("{other:?}"),
        }
        let tail_resp = tail.msgs[0].1.clone();
        deliver(&mut fresh, 1, tail_resp);
        // Caught up past the target: the catch-up state cleared, so the
        // pending retry timer becomes a no-op.
        let mut fxq = Effects::new();
        fresh.on_timer(20 * MS, Timer::CatchupRetry, &mut fxq);
        assert!(fxq.msgs.is_empty() && fxq.timers.is_empty());
        assert_eq!(fresh.sm.digest(), peer.sm.digest());
        assert!(fx4
            .announces
            .iter()
            .any(|a| matches!(a, Announce::SnapshotInstalled { base: 20, .. })));
        // Exactly-once survives the transfer: a re-chosen old command is
        // deduped (cached reply, no re-execution).
        let before = fresh.executed;
        let fx5 = deliver(&mut fresh, 0, Msg::Chosen { slot: 20, value: cmd(7, 20, b"skv") });
        assert_eq!(fresh.executed, before);
        assert!(fx5
            .msgs
            .iter()
            .any(|(to, m)| *to == 7 && matches!(m, Msg::ClientReply { seq: 20, .. })));
        // And new commands flow normally after catch-up.
        deliver(&mut fresh, 0, Msg::Chosen { slot: 21, value: cmd(7, 21, b"skv") });
        assert_eq!(fresh.exec_watermark, 22);
    }

    #[test]
    fn snapshot_request_within_retained_log_served_entries_only() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        for s in 0..5 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        // Nothing truncated: the retained log covers the whole gap, so no
        // state transfer is needed — just the entries.
        let fx = deliver(&mut r, 9, Msg::SnapshotRequest { from: 0 });
        match &fx.msgs[0].1 {
            Msg::SnapshotResp { base, state, entries } => {
                assert_eq!(*base, 0);
                assert!(state.is_empty());
                assert_eq!(entries.len(), 5);
            }
            other => panic!("{other:?}"),
        }
        // A second replica applies the entries-only response and
        // converges by normal execution.
        let mut b = Replica::new(2, Box::new(KvStore::new()));
        let resp = fx.msgs[0].1.clone();
        deliver(&mut b, 1, resp);
        assert_eq!(b.exec_watermark, 5);
        assert_eq!(b.sm.digest(), r.sm.digest());
        assert_eq!(b.snapshots_installed, 0, "no state install needed");
    }

    #[test]
    fn stale_snapshot_resp_ignored() {
        let mut r = snapshotting_replica(4);
        for s in 0..10 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        let digest = r.sm.digest();
        // A response whose base is behind our watermark must not regress
        // state; malformed state must be refused.
        deliver(&mut r, 2, Msg::SnapshotResp { base: 3, state: vec![], entries: vec![] });
        assert_eq!(r.exec_watermark, 10);
        assert_eq!(r.sm.digest(), digest);
        deliver(
            &mut r,
            2,
            Msg::SnapshotResp { base: 99, state: b"garbage".to_vec(), entries: vec![] },
        );
        assert_eq!(r.exec_watermark, 10);
        assert_eq!(r.snapshots_installed, 0);
    }

    // ---- Durability (DESIGN.md §Durability) ----

    fn deliver_at(r: &mut Replica, from: NodeId, m: Msg, now: Time) -> Effects {
        let mut fx = Effects::new();
        r.on_msg(now, from, m, &mut fx);
        fx
    }

    #[test]
    fn crash_recovery_restores_snapshot_and_chosen_tail() {
        use crate::storage::MemStorage;
        let mut r = snapshotting_replica(4);
        r.attach_storage(Box::new(MemStorage::new()));
        for s in 0..10 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        tick(&mut r, MS); // snapshot at 10, truncate below 6, compact the WAL
        for s in 10..12 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        let digest = r.sm.digest();
        // kill -9: the disk survives, the process state does not.
        let disk = r.take_storage().expect("storage attached");
        let mut b = snapshotting_replica(4);
        b.attach_storage(disk);
        b.recover();
        assert_eq!(b.exec_watermark, 12);
        assert_eq!(b.sm.digest(), digest);
        assert_eq!(b.client_table[&7].highest, 12, "dedup cursor survives the crash");
        assert_eq!(b.snapshots_taken, 0, "recovery installs, it does not re-snapshot");
        // Exactly-once survives the crash: a re-chosen pre-crash command
        // is deduped (cached reply, no re-execution).
        let executed = b.executed;
        let fx = deliver(&mut b, 0, Msg::Chosen { slot: 12, value: cmd(7, 12, b"skv") });
        assert_eq!(b.executed, executed, "retry must not re-execute after recovery");
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 7 && matches!(m, Msg::ClientReply { seq: 12, .. })));
        // And fresh commands continue from the recovered watermark.
        deliver(&mut b, 0, Msg::Chosen { slot: 13, value: cmd(7, 13, b"skv") });
        assert_eq!(b.exec_watermark, 14);
    }

    #[test]
    fn chunked_transfer_resumes_from_cursor_after_loss() {
        let mut peer = snapshotting_replica(4);
        for s in 0..20 {
            deliver(&mut peer, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        tick(&mut peer, MS);
        peer.chunk_bytes = 16; // force a many-chunk transfer
        let mut fresh = snapshotting_replica(4);
        fresh.id = 2;
        let mut fx = Effects::new();
        fresh.on_msg(10 * MS, 0, Msg::CatchUp { below: 16, peer: 1 }, &mut fx);
        let chunks: Vec<Msg> = deliver(&mut peer, 2, Msg::SnapshotRequest { from: 0 })
            .msgs
            .into_iter()
            .map(|(_, m)| m)
            .collect();
        assert!(chunks.len() >= 3, "chunk size 16 must split the state: {}", chunks.len());
        match &chunks[0] {
            Msg::SnapshotChunk { total, .. } => assert_eq!(*total as usize, chunks.len()),
            other => panic!("{other:?}"),
        }
        // Deliver only the first two chunks; the rest are "lost".
        for c in chunks.iter().take(2) {
            deliver_at(&mut fresh, 1, c.clone(), 11 * MS);
        }
        assert_eq!(fresh.snapshots_installed, 0);
        // First retry tick: the stream made progress since the last
        // tick — no resume yet.
        let mut fx1 = Effects::new();
        fresh.on_timer(11 * MS + CATCHUP_RETRY, Timer::CatchupRetry, &mut fx1);
        assert!(fx1.msgs.is_empty(), "{:?}", fx1.msgs);
        // Second tick: stalled — resume from the cursor (chunk 2).
        let mut fx2 = Effects::new();
        fresh.on_timer(11 * MS + 2 * CATCHUP_RETRY, Timer::CatchupRetry, &mut fx2);
        let resume = fx2.msgs.iter().find_map(|(to, m)| match m {
            Msg::SnapshotResume { base, next } => Some((*to, *base, *next)),
            _ => None,
        });
        assert_eq!(resume, Some((1, 20, 2)));
        // The peer re-sends exactly the missing suffix, from the cursor.
        let rest = deliver(&mut peer, 2, Msg::SnapshotResume { base: 20, next: 2 });
        assert_eq!(rest.msgs.len(), chunks.len() - 2);
        match &rest.msgs[0].1 {
            Msg::SnapshotChunk { seq: 2, .. } => {}
            other => panic!("{other:?}"),
        }
        for (_, m) in rest.msgs {
            deliver_at(&mut fresh, 1, m, 200 * MS);
        }
        assert_eq!(fresh.snapshots_installed, 1);
        assert_eq!(fresh.exec_watermark, 20);
        assert_eq!(fresh.sm.digest(), peer.sm.digest());
    }

    #[test]
    fn resume_for_unknown_base_restarts_transfer() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        for s in 0..5 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"skv") });
        }
        // The sender restarted (or replaced its snapshot): a resume for
        // a base it no longer holds restarts the transfer from its
        // current best — nothing truncated here, so entries-only.
        let fx = deliver(&mut r, 9, Msg::SnapshotResume { base: 99, next: 3 });
        match &fx.msgs[0].1 {
            Msg::SnapshotResp { base: 0, state, entries } => {
                assert!(state.is_empty());
                assert_eq!(entries.len(), 5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn mid_stream_chunk_after_receiver_restart_requests_prefix() {
        let mut r = snapshotting_replica(4);
        // A chunk with seq > 0 for a transfer we are not assembling
        // (receiver restart lost the partial buffer): ask the sender to
        // re-send from chunk 0 rather than dropping the stream.
        let fx = deliver(&mut r, 1, Msg::SnapshotChunk {
            base: 50,
            seq: 3,
            total: 8,
            bytes: vec![1, 2],
        });
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 1 && matches!(m, Msg::SnapshotResume { base: 50, next: 0 })));
        assert_eq!(r.pending_read_count(), 0);
    }

    // ---- Linearizable reads ----

    /// A real kv `set k=v` command (the `cmd` helper above carries raw
    /// bytes, which the KvStore treats as malformed — fine for the
    /// exec-count tests, wrong for value assertions).
    fn kv_set(client: NodeId, seq: u64) -> Value {
        Value::Cmd(Command { client, seq, payload: KvStore::enc_set(b"k", b"v") })
    }

    #[test]
    fn leased_read_waits_for_fresh_grant_then_serves() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        r.proposers = vec![0];
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: kv_set(7, 1) });
        // An active lease from before the read.
        let mut fx = Effects::new();
        r.on_msg(
            MS,
            0,
            Msg::LeaseGrant { round: crate::round::Round::first(0, 0), upto: 1, granted_at: MS, valid_until: 60 * MS },
            &mut fx,
        );
        assert!(r.lease_active(2 * MS));
        // Read arrives at 2 ms: it must NOT be served off the old grant
        // (a write could have been acknowledged between the grant and
        // the read) — it waits for the next grant.
        let mut fx2 = Effects::new();
        r.on_msg(2 * MS, 9, Msg::Read { group: 0, seq: 1, payload: KvStore::enc_get(b"k") }, &mut fx2);
        assert!(fx2.msgs.iter().all(|(_, m)| !matches!(m, Msg::ReadReply { .. })));
        assert_eq!(r.pending_read_count(), 1);
        // No ReadIndex traffic on the leased path.
        assert!(fx2.msgs.iter().all(|(_, m)| !matches!(m, Msg::ReadIndexReq { .. })));
        // The next grant (issued after arrival) resolves and serves it.
        let mut fx3 = Effects::new();
        r.on_msg(
            3 * MS,
            0,
            Msg::LeaseGrant { round: crate::round::Round::first(0, 0), upto: 1, granted_at: 3 * MS, valid_until: 60 * MS },
            &mut fx3,
        );
        let reply = fx3.msgs.iter().find_map(|(to, m)| match m {
            Msg::ReadReply { seq, result, .. } => Some((*to, *seq, result.clone())),
            _ => None,
        });
        assert_eq!(reply, Some((9, 1, b"v".to_vec())));
        assert_eq!(r.reads_leased, 1);
        assert_eq!(r.pending_read_count(), 0);
    }

    #[test]
    fn leased_read_blocks_until_applied_covers_watermark() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        // Grant active, read arrives, next grant carries upto = 2 but
        // we have applied nothing: the read must wait for execution.
        let g = |at: Time, upto: Slot| Msg::LeaseGrant {
            round: crate::round::Round::first(0, 0),
            upto,
            granted_at: at,
            valid_until: 100 * MS,
        };
        deliver(&mut r, 0, g(MS, 0));
        let mut fx = Effects::new();
        r.on_msg(2 * MS, 9, Msg::Read { group: 0, seq: 1, payload: KvStore::enc_get(b"k") }, &mut fx);
        let fx2 = deliver(&mut r, 0, g(3 * MS, 2));
        assert!(
            fx2.msgs.iter().all(|(_, m)| !matches!(m, Msg::ReadReply { .. })),
            "must not serve below the read index"
        );
        // Applying slots 0..2 unblocks it, with the freshest value.
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: kv_set(7, 1) });
        let fx3 = deliver(&mut r, 0, Msg::Chosen { slot: 1, value: kv_set(7, 2) });
        assert!(fx3
            .msgs
            .iter()
            .any(|(to, m)| *to == 9 && matches!(m, Msg::ReadReply { seq: 1, .. })));
    }

    #[test]
    fn unleased_read_takes_read_index_path() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        r.proposers = vec![0, 5];
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: kv_set(7, 1) });
        // No lease: the read triggers one ReadIndexReq to the observed
        // leader (the Chosen sender).
        let mut fx = Effects::new();
        r.on_msg(MS, 9, Msg::Read { group: 0, seq: 1, payload: KvStore::enc_get(b"k") }, &mut fx);
        let req = fx.msgs.iter().find_map(|(to, m)| match m {
            Msg::ReadIndexReq { id } => Some((*to, *id)),
            _ => None,
        });
        let (to, id) = req.expect("ReadIndexReq sent");
        assert_eq!(to, 0, "targets the observed leader");
        // A second read shares the outstanding request (batching).
        let mut fxb = Effects::new();
        r.on_msg(MS + 1, 8, Msg::Read { group: 0, seq: 1, payload: KvStore::enc_get(b"k") }, &mut fxb);
        assert!(fxb.msgs.iter().all(|(_, m)| !matches!(m, Msg::ReadIndexReq { .. })));
        // The response resolves both (they arrived before... the second
        // arrived after the send, so it needs a fresh request).
        let mut fx2 = Effects::new();
        r.on_msg(2 * MS, 0, Msg::ReadIndexResp { id, upto: 1 }, &mut fx2);
        assert!(fx2
            .msgs
            .iter()
            .any(|(to2, m)| *to2 == 9 && matches!(m, Msg::ReadReply { seq: 1, .. })));
        assert_eq!(r.reads_indexed, 1);
        // The uncovered read re-asked.
        assert!(fx2.msgs.iter().any(|(_, m)| matches!(m, Msg::ReadIndexReq { .. })));
    }

    #[test]
    fn read_with_no_possible_target_redirects() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        // No lease, no proposers, no observed leader: NotLeaseholder.
        let mut fx = Effects::new();
        r.on_msg(MS, 9, Msg::Read { group: 0, seq: 1, payload: vec![] }, &mut fx);
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 9 && matches!(m, Msg::NotLeaseholder { .. })));
        assert_eq!(r.pending_read_count(), 0);
    }

    #[test]
    fn read_retry_falls_back_and_rotates() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        r.proposers = vec![0, 5];
        // Active lease, read queued on the grant path...
        deliver(
            &mut r,
            0,
            Msg::LeaseGrant { round: crate::round::Round::first(0, 0), upto: 0, granted_at: 0, valid_until: 5 * MS },
        );
        let mut fx = Effects::new();
        r.on_msg(MS, 9, Msg::Read { group: 0, seq: 1, payload: vec![] }, &mut fx);
        assert!(fx.timers.iter().any(|(_, t)| *t == Timer::ReadIndexRetry));
        // ... but grants stop (lease lapses). The retry tick converts it
        // to the ReadIndex path.
        let mut fx2 = Effects::new();
        r.on_timer(MS + READ_RETRY, Timer::ReadIndexRetry, &mut fx2);
        assert!(fx2.msgs.iter().any(|(_, m)| matches!(m, Msg::ReadIndexReq { .. })));
        assert!(fx2.timers.iter().any(|(_, t)| *t == Timer::ReadIndexRetry));
        // An unanswered request rotates to another proposer. The hint
        // from the first grant (node 0) is dropped; hint cycling covers
        // the proposer list.
        let mut fx3 = Effects::new();
        r.on_timer(MS + 2 * READ_RETRY, Timer::ReadIndexRetry, &mut fx3);
        let retarget = fx3.msgs.iter().find_map(|(to, m)| match m {
            Msg::ReadIndexReq { .. } => Some(*to),
            _ => None,
        });
        assert!(retarget.is_some());
        // Expiry: a read stuck past READ_EXPIRE is dropped.
        let mut fx4 = Effects::new();
        r.on_timer(MS + READ_EXPIRE, Timer::ReadIndexRetry, &mut fx4);
        assert_eq!(r.pending_read_count(), 0);
    }

    /// Regression (satellite): a replica whose log was snapshot-truncated
    /// still serves a correct ReadIndex read after catch-up — the
    /// watermark comparison must use the post-restore applied index, not
    /// the raw chosen-log length (after a snapshot install the log holds
    /// only the tail, far fewer entries than the applied prefix).
    #[test]
    fn snapshot_truncated_replica_serves_read_index_read() {
        // Peer executes 20 commands, snapshots, truncates to a 4-tail.
        let mut peer = snapshotting_replica(4);
        for s in 0..20 {
            deliver(&mut peer, 0, Msg::Chosen { slot: s, value: kv_set(7, s + 1) });
        }
        tick(&mut peer, MS);
        // Fresh replica catches up purely via snapshot transfer.
        let mut fresh = snapshotting_replica(4);
        fresh.id = 2;
        fresh.proposers = vec![0];
        let resp = deliver(&mut peer, 2, Msg::SnapshotRequest { from: 0 });
        let snap = resp.msgs[0].1.clone();
        deliver(&mut fresh, 1, snap);
        assert_eq!(fresh.exec_watermark, 20);
        assert!(fresh.log_len() < 20, "log holds at most the tail after install");
        // A read with read index 20 must be served: applied (20) covers
        // it even though the raw log length does not.
        let mut fx = Effects::new();
        fresh.on_msg(
            10 * MS,
            9,
            Msg::Read { group: 0, seq: 1, payload: KvStore::enc_get(b"k") },
            &mut fx,
        );
        let req_id = fx
            .msgs
            .iter()
            .find_map(|(_, m)| match m {
                Msg::ReadIndexReq { id } => Some(*id),
                _ => None,
            })
            .expect("fallback ReadIndexReq");
        let fx2 = deliver(&mut fresh, 0, Msg::ReadIndexResp { id: req_id, upto: 20 });
        let reply = fx2.msgs.iter().find_map(|(to, m)| match m {
            Msg::ReadReply { seq, result, .. } => Some((*to, *seq, result.clone())),
            _ => None,
        });
        assert_eq!(reply, Some((9, 1, b"v".to_vec())), "post-restore applied index must serve");
    }

    #[test]
    fn replica_snapshot_roundtrip_via_encode_install() {
        let mut a = Replica::new(1, Box::new(KvStore::new()));
        for s in 0..7 {
            deliver(&mut a, 0, Msg::Chosen { slot: s, value: cmd(9, s + 1, b"skv") });
        }
        let snap = a.encode_snapshot();
        let mut b = Replica::new(2, Box::new(KvStore::new()));
        assert!(b.install_snapshot(a.exec_watermark, &snap));
        assert_eq!(b.exec_watermark, 7);
        assert_eq!(b.sm.digest(), a.sm.digest());
        assert_eq!(b.client_table[&9].highest, 7);
        // Base mismatch refused.
        let mut c = Replica::new(3, Box::new(KvStore::new()));
        assert!(!c.install_snapshot(99, &snap));
        assert_eq!(c.exec_watermark, 0);
    }
}
