//! State machine replicas (§4.1, §5.3).
//!
//! Replicas insert chosen commands into their logs, execute the log in
//! prefix order against a pluggable [`crate::statemachine::StateMachine`],
//! and send execution results back to clients. They acknowledge their
//! contiguous stored prefix to the leader (`ReplicaAck`), which drives GC
//! Scenario 3 (a prefix stored on `f+1` replicas may be garbage
//! collected), and they serve `ReadPrefix` so a newly elected leader can
//! learn the chosen prefix (§4.1: "by communicating with the replicas").

use crate::msg::{Command, Msg, Value};
use crate::node::{Announce, Effects, Node, Timer};
use crate::statemachine::StateMachine;
use crate::{NodeId, Slot, Time};
use std::collections::{BTreeMap, HashMap};

/// Per-client execution history: dedup cursor plus a bounded window of
/// recent results. Pipelined clients can lose the reply to seq `k` while
/// seqs `k+1..` already executed, so caching only the latest result is
/// not enough to answer retries of any recently executed request.
#[derive(Debug, Default)]
pub struct ClientHistory {
    /// Highest executed seq for this client (commands at or below it are
    /// duplicates, never re-executed).
    pub highest: u64,
    /// Results of the most recent [`RESULT_CACHE`] executed seqs.
    pub recent: BTreeMap<u64, Vec<u8>>,
}

/// How many per-client results a replica retains for retry re-replies.
/// Covers the largest client in-flight window (workload specs clamp
/// their windows to this bound for exactly that reason).
pub const RESULT_CACHE: usize = crate::workload::MAX_IN_FLIGHT;

/// A state machine replica.
pub struct Replica {
    pub id: NodeId,
    /// Chosen log.
    pub log: BTreeMap<Slot, Value>,
    /// Next slot to execute; slots `< exec_watermark` are executed.
    pub exec_watermark: Slot,
    /// The application state machine.
    pub sm: Box<dyn StateMachine>,
    /// Deduplication + retry re-reply cache, per client.
    pub client_table: HashMap<NodeId, ClientHistory>,
    /// Number of commands executed (metrics).
    pub executed: u64,
    /// Emit an `Announce::Executed` per slot (off by default: it is 3
    /// allocations per command across a 2f+1 replica group on the hottest
    /// path; the TCP integration test and debug tooling enable it).
    pub announce_execs: bool,
}

impl Replica {
    pub fn new(id: NodeId, sm: Box<dyn StateMachine>) -> Replica {
        Replica {
            id,
            log: BTreeMap::new(),
            exec_watermark: 0,
            sm,
            client_table: HashMap::new(),
            executed: 0,
            announce_execs: false,
        }
    }

    /// Execute every contiguous chosen slot, reply to clients, and ack the
    /// new prefix to the leader that informed us.
    fn execute_ready(&mut self, leader: NodeId, fx: &mut Effects) {
        let before = self.exec_watermark;
        loop {
            let Some(value) = self.log.get(&self.exec_watermark) else {
                break;
            };
            // Split borrows: the commands stay borrowed from the log
            // while the disjoint execution fields are mutated — no
            // per-slot clone on the execution hot path.
            match value {
                Value::Cmd(cmd) => exec_commands(
                    std::slice::from_ref(cmd),
                    &mut self.client_table,
                    self.sm.as_mut(),
                    &mut self.executed,
                    fx,
                ),
                // Phase 2 batching: unpack and execute the whole batch
                // through one `StateMachine::apply_many` invocation,
                // replying to each client individually.
                Value::Batch(cmds) => exec_commands(
                    cmds,
                    &mut self.client_table,
                    self.sm.as_mut(),
                    &mut self.executed,
                    fx,
                ),
                Value::Noop | Value::Reconfig(_) => {}
            }
            if self.announce_execs {
                fx.announce(Announce::Executed { slot: self.exec_watermark, replica: self.id });
            }
            self.exec_watermark += 1;
        }
        if self.exec_watermark != before {
            fx.send(leader, Msg::ReplicaAck { upto: self.exec_watermark });
        }
    }

}

/// Execute a run of commands from one slot: deduplicate retries
/// (re-replying with the cached result), then apply the fresh suffix as a
/// single state-machine batch, in order, with one reply per command.
///
/// A free function over the replica's disjoint execution fields so the
/// commands can stay borrowed from the log (no clone per executed slot).
fn exec_commands(
    cmds: &[Command],
    client_table: &mut HashMap<NodeId, ClientHistory>,
    sm: &mut dyn StateMachine,
    executed: &mut u64,
    fx: &mut Effects,
) {
    let mut fresh: Vec<&Command> = Vec::with_capacity(cmds.len());
    for cmd in cmds {
        let dup = client_table
            .get(&cmd.client)
            .map_or(false, |h| h.highest >= cmd.seq);
        if dup {
            // Re-chosen retry of an executed command: re-reply with the
            // cached result, do not re-execute.
            if let Some(result) = client_table
                .get(&cmd.client)
                .and_then(|h| h.recent.get(&cmd.seq))
            {
                fx.send(
                    cmd.client,
                    Msg::ClientReply { seq: cmd.seq, result: result.clone() },
                );
            }
        } else {
            fresh.push(cmd);
        }
    }
    if fresh.is_empty() {
        return;
    }
    let payloads: Vec<&[u8]> = fresh.iter().map(|c| c.payload.as_slice()).collect();
    let results = sm.apply_many(&payloads);
    debug_assert_eq!(results.len(), fresh.len());
    for (cmd, result) in fresh.iter().zip(results) {
        *executed += 1;
        let h = client_table.entry(cmd.client).or_default();
        h.highest = h.highest.max(cmd.seq);
        h.recent.insert(cmd.seq, result.clone());
        while h.recent.len() > RESULT_CACHE {
            let oldest = *h.recent.keys().next().unwrap();
            h.recent.remove(&oldest);
        }
        fx.send(cmd.client, Msg::ClientReply { seq: cmd.seq, result });
    }
}

impl Node for Replica {
    fn on_msg(&mut self, _now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::Chosen { slot, value } => {
                // Idempotent insert: chosen values never conflict (safety),
                // so a duplicate insert is a no-op.
                self.log.entry(slot).or_insert(value);
                let before = self.exec_watermark;
                self.execute_ready(from, fx);
                if self.exec_watermark == before && slot > self.exec_watermark {
                    // We have a hole: ack our (unchanged) watermark so the
                    // leader can re-send the missing entries.
                    fx.send(from, Msg::ReplicaAck { upto: self.exec_watermark });
                }
            }
            // A (new) leader asks for the chosen prefix (§4.1). The
            // requested start may exceed our watermark (the leader already
            // knows more than us): clamp the range.
            Msg::ReadPrefix { from: from_slot } => {
                let start = from_slot.min(self.exec_watermark);
                let entries: Vec<(Slot, Value)> = self
                    .log
                    .range(start..self.exec_watermark)
                    .map(|(s, v)| (*s, v.clone()))
                    .collect();
                fx.send(from, Msg::PrefixResp { entries, upto: self.exec_watermark });
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, _now: Time, _timer: Timer, _fx: &mut Effects) {}

    fn role(&self) -> &'static str {
        "replica"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Command;
    use crate::statemachine::{KvStore, Noop};

    fn cmd(client: NodeId, seq: u64, payload: &[u8]) -> Value {
        Value::Cmd(Command { client, seq, payload: payload.to_vec() })
    }

    fn deliver(r: &mut Replica, from: NodeId, m: Msg) -> Effects {
        let mut fx = Effects::new();
        r.on_msg(0, from, m, &mut fx);
        fx
    }

    #[test]
    fn executes_in_prefix_order() {
        let mut r = Replica::new(1, Box::new(Noop));
        // Slot 1 arrives first: no execution (hole at 0).
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 1, value: cmd(9, 0, b"b") });
        assert_eq!(r.exec_watermark, 0);
        assert!(fx.msgs.iter().all(|(_, m)| !matches!(m, Msg::ClientReply { .. })));
        // Slot 0 arrives: both execute, in order.
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(8, 0, b"a") });
        assert_eq!(r.exec_watermark, 2);
        let replies: Vec<&NodeId> = fx
            .msgs
            .iter()
            .filter(|(_, m)| matches!(m, Msg::ClientReply { .. }))
            .map(|(to, _)| to)
            .collect();
        assert_eq!(replies, vec![&8, &9]);
        // Acked the new prefix to the leader.
        assert!(fx.msgs.contains(&(0, Msg::ReplicaAck { upto: 2 })));
    }

    #[test]
    fn noop_advances_without_reply() {
        let mut r = Replica::new(1, Box::new(Noop));
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 0, value: Value::Noop });
        assert_eq!(r.exec_watermark, 1);
        assert!(fx.msgs.iter().all(|(_, m)| !matches!(m, Msg::ClientReply { .. })));
    }

    #[test]
    fn duplicate_command_not_reexecuted() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        // set k=1
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 0, b"skv") });
        assert_eq!(r.executed, 1);
        // Same (client, seq) re-chosen at a later slot (leader retry path):
        // executed once only, but the client still gets a reply.
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 1, value: cmd(7, 0, b"skv") });
        assert_eq!(r.executed, 1);
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 7 && matches!(m, Msg::ClientReply { seq: 0, .. })));
    }

    #[test]
    fn read_prefix() {
        let mut r = Replica::new(1, Box::new(Noop));
        for s in 0..4 {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: Value::Noop });
        }
        let fx = deliver(&mut r, 5, Msg::ReadPrefix { from: 1 });
        match &fx.msgs[0].1 {
            Msg::PrefixResp { entries, upto } => {
                assert_eq!(*upto, 4);
                assert_eq!(entries.len(), 3);
                assert_eq!(entries[0].0, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_executes_in_order_with_per_command_replies() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        let batch = Value::Batch(vec![
            Command { client: 7, seq: 1, payload: KvStore::enc_set(b"k", b"v1") },
            Command { client: 8, seq: 1, payload: KvStore::enc_get(b"k") },
            Command { client: 7, seq: 2, payload: KvStore::enc_set(b"k", b"v2") },
        ]);
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 0, value: batch });
        assert_eq!(r.exec_watermark, 1);
        assert_eq!(r.executed, 3);
        // Per-command replies, in batch order: client 8's get observes
        // client 7's earlier set (FIFO within the batch).
        let replies: Vec<(NodeId, u64, Vec<u8>)> = fx
            .msgs
            .iter()
            .filter_map(|(to, m)| match m {
                Msg::ClientReply { seq, result } => Some((*to, *seq, result.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(replies.len(), 3);
        assert_eq!(replies[0], (7, 1, b"OK".to_vec()));
        assert_eq!(replies[1], (8, 1, b"v1".to_vec()));
        assert_eq!(replies[2], (7, 2, b"OK".to_vec()));
        // One ack for the new prefix.
        assert!(fx.msgs.contains(&(0, Msg::ReplicaAck { upto: 1 })));
    }

    #[test]
    fn rechosen_batch_not_reexecuted() {
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        let batch = Value::Batch(vec![
            Command { client: 7, seq: 1, payload: KvStore::enc_set(b"k", b"v1") },
            Command { client: 8, seq: 1, payload: KvStore::enc_set(b"j", b"w") },
        ]);
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: batch.clone() });
        assert_eq!(r.executed, 2);
        // The same batch re-chosen at a later slot (leader retry across a
        // reconfiguration): exactly-once execution, but both clients get
        // their cached replies again.
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 1, value: batch });
        assert_eq!(r.executed, 2);
        let replies = fx
            .msgs
            .iter()
            .filter(|(_, m)| matches!(m, Msg::ClientReply { .. }))
            .count();
        assert_eq!(replies, 2);
    }

    #[test]
    fn retry_of_older_pipelined_seq_gets_cached_reply() {
        // A pipelined client lost the reply to seq 1 while seq 2 already
        // executed: the retry (re-chosen at a later slot) must still get
        // seq 1's cached result, not silence.
        let mut r = Replica::new(1, Box::new(KvStore::new()));
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 1, b"skv") });
        deliver(&mut r, 0, Msg::Chosen { slot: 1, value: cmd(7, 2, b"gk") });
        assert_eq!(r.executed, 2);
        let fx = deliver(&mut r, 0, Msg::Chosen { slot: 2, value: cmd(7, 1, b"skv") });
        assert_eq!(r.executed, 2, "retry must not re-execute");
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 7 && matches!(m, Msg::ClientReply { seq: 1, .. })));
    }

    #[test]
    fn result_cache_is_bounded() {
        let mut r = Replica::new(1, Box::new(Noop));
        for s in 0..(RESULT_CACHE as u64 + 50) {
            deliver(&mut r, 0, Msg::Chosen { slot: s, value: cmd(7, s + 1, b"x") });
        }
        let h = r.client_table.get(&7).unwrap();
        assert_eq!(h.recent.len(), RESULT_CACHE);
        assert_eq!(h.highest, RESULT_CACHE as u64 + 50);
        // Oldest entries were evicted.
        assert!(!h.recent.contains_key(&1));
        assert!(h.recent.contains_key(&(RESULT_CACHE as u64 + 50)));
    }

    #[test]
    fn chosen_is_idempotent() {
        let mut r = Replica::new(1, Box::new(Noop));
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 0, b"x") });
        let executed = r.executed;
        deliver(&mut r, 0, Msg::Chosen { slot: 0, value: cmd(7, 0, b"x") });
        assert_eq!(r.executed, executed);
        assert_eq!(r.exec_watermark, 1);
    }
}
