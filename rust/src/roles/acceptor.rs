//! The Paxos acceptor (Algorithm 2), extended per-slot for MultiPaxos and
//! with the chosen-prefix watermark that supports GC Scenario 3 (§5.2).
//!
//! A Matchmaker Paxos acceptor is *identical* to a Paxos acceptor — all the
//! reconfiguration machinery lives in the matchmakers and the
//! proposer/leader. This is the heart of the paper's generality argument.

use crate::msg::{Msg, SlotVote, Value};
use crate::node::{Announce, Effects, Node, Timer};
use crate::round::Round;
use crate::storage::{Storage, WalRecord};
use crate::{NodeId, Slot, Time};
use std::collections::BTreeMap;

/// Per-slot vote state: the largest round voted in (`vr`) and the value
/// voted for (`vv`).
#[derive(Clone, Debug, PartialEq)]
pub struct Vote {
    /// Round of the vote.
    pub vr: Round,
    /// Value voted for.
    pub vv: Value,
}

/// A (multi-slot) Flexible Paxos acceptor.
#[derive(Debug)]
pub struct Acceptor {
    /// This node's id.
    pub id: NodeId,
    /// Largest round seen (`r` in Algorithm 2); `None` is the paper's `-1`.
    pub round: Option<Round>,
    /// Per-slot votes.
    pub votes: BTreeMap<Slot, Vote>,
    /// Slots `< chosen_watermark` are known chosen *and* persisted on f+1
    /// replicas (set by the leader's `PrefixPersisted`, §5.3 Scenario 3).
    /// Reported in Phase1B so a recovering leader skips re-deciding them.
    pub chosen_watermark: Slot,
    /// Also serve fast rounds (Matchmaker Fast Paxos, §7). A fast acceptor
    /// votes for the first value it sees in a fast round.
    pub fast: bool,
    /// Durable log, when attached (`repro run --data-dir`, recovery
    /// tests). `None` — the sim default — keeps the hot path free of
    /// clones and I/O. With a log attached, every promise/vote/watermark
    /// is appended (and fsync'd by [`crate::storage::WalStorage`])
    /// *before* the corresponding ack is queued: fsync-before-ack, the
    /// ordering that keeps the P1 ∩ P2 intersection argument sound
    /// across `kill -9` (DESIGN.md §Durability).
    storage: Option<Box<dyn Storage>>,
}

impl Acceptor {
    /// A classic acceptor (no fast rounds).
    pub fn new(id: NodeId) -> Acceptor {
        Acceptor {
            id,
            round: None,
            votes: BTreeMap::new(),
            chosen_watermark: 0,
            fast: false,
            storage: None,
        }
    }

    /// An acceptor that also participates in fast rounds (§7).
    pub fn new_fast(id: NodeId) -> Acceptor {
        Acceptor { fast: true, ..Acceptor::new(id) }
    }

    fn seen_geq(&self, r: Round) -> bool {
        matches!(self.round, Some(cur) if cur > r)
    }

    /// Drop vote state below the chosen watermark (memory reclamation; the
    /// values are durable on f+1 replicas).
    pub fn compact(&mut self) {
        let w = self.chosen_watermark;
        self.votes.retain(|&s, _| s >= w);
    }

    /// Attach a durable log. Call before the node starts; follow with
    /// [`Acceptor::recover`] when rejoining after a crash.
    pub fn attach_storage(&mut self, storage: Box<dyn Storage>) {
        self.storage = Some(storage);
    }

    /// Detach and return the durable log (crash simulation: the "disk"
    /// survives the process, so tests move it into a fresh instance).
    pub fn take_storage(&mut self) -> Option<Box<dyn Storage>> {
        self.storage.take()
    }

    /// Append `rec` to the attached log, if any. A storage failure is
    /// fatal by design: an acceptor that cannot persist must stop
    /// acking, and crashing before the ack is queued is exactly the
    /// failure mode the protocol already tolerates.
    fn persist(&mut self, rec: WalRecord) {
        if let Some(s) = self.storage.as_mut() {
            s.append(&rec).expect("acceptor wal append failed");
        }
    }

    /// Rewrite the durable log to the live set — promise + watermark +
    /// surviving votes — reclaiming everything the chosen-prefix
    /// watermark retired (watermark-driven truncation, §5.3).
    fn compact_storage(&mut self) {
        if self.storage.is_none() {
            return;
        }
        let mut live = Vec::with_capacity(self.votes.len() + 2);
        if let Some(round) = self.round {
            live.push(WalRecord::Promise { round });
        }
        live.push(WalRecord::Watermark { upto: self.chosen_watermark });
        for (&slot, v) in &self.votes {
            live.push(WalRecord::Vote { slot, vr: v.vr, vv: v.vv.clone() });
        }
        let s = self.storage.as_mut().unwrap();
        s.compact(&live).expect("acceptor wal compact failed");
    }

    /// Rebuild promise/vote state by replaying the attached log — the
    /// `kill -9` recovery path. Replay is idempotent over the duplicate
    /// records a crash mid-`compact` can leave behind: promises and
    /// watermarks only ratchet up, votes are last-write-wins per slot.
    /// Announces [`Announce::AcceptorRecovered`] so the
    /// recovery-soundness invariant can compare the restored state
    /// against everything durably acked before the crash.
    pub fn recover(&mut self, fx: &mut Effects) {
        let Some(s) = self.storage.as_mut() else {
            return;
        };
        let recs = s.replay().expect("acceptor wal replay failed");
        for rec in recs {
            match rec {
                WalRecord::Promise { round } => {
                    if self.round.map_or(true, |cur| round > cur) {
                        self.round = Some(round);
                    }
                }
                WalRecord::Vote { slot, vr, vv } => {
                    self.votes.insert(slot, Vote { vr, vv });
                }
                WalRecord::Watermark { upto } => {
                    if upto > self.chosen_watermark {
                        self.chosen_watermark = upto;
                    }
                }
                _ => {}
            }
        }
        self.compact();
        fx.announce(Announce::AcceptorRecovered {
            node: self.id,
            round: self.round,
            watermark: self.chosen_watermark,
            votes: self.votes.iter().map(|(&s, v)| (s, v.vr)).collect(),
        });
    }
}

impl Node for Acceptor {
    fn on_msg(&mut self, _now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            // Phase 1: promise not to vote in any round < i, report votes
            // for every slot >= from_slot (bulk Phase1, §4.1) plus the
            // chosen-prefix watermark (Scenario 3).
            Msg::Phase1A { round, from_slot } => {
                // Equal-round re-sends are answered again (dropped-message
                // recovery); only strictly higher seen rounds refuse.
                if self.seen_geq(round) {
                    fx.send(from, Msg::Nack { round, higher: self.round.unwrap() });
                    return;
                }
                let raised = self.round != Some(round);
                self.round = Some(round);
                if raised && self.storage.is_some() {
                    self.persist(WalRecord::Promise { round });
                    fx.announce(Announce::DurablePromise { node: self.id, round });
                }
                let votes: Vec<SlotVote> = self
                    .votes
                    .range(from_slot.max(self.chosen_watermark)..)
                    .map(|(&slot, v)| SlotVote { slot, vr: v.vr, vv: v.vv.clone() })
                    .collect();
                fx.send(
                    from,
                    Msg::Phase1B { round, votes, chosen_watermark: self.chosen_watermark },
                );
            }

            // Phase 2: vote for the value unless promised to a higher round.
            Msg::Phase2A { round, slot, value } => {
                if self.seen_geq(round) {
                    fx.send(from, Msg::Nack { round, higher: self.round.unwrap() });
                    return;
                }
                let raised = self.round != Some(round);
                self.round = Some(round);
                if self.storage.is_some() {
                    if raised {
                        self.persist(WalRecord::Promise { round });
                        fx.announce(Announce::DurablePromise { node: self.id, round });
                    }
                    self.persist(WalRecord::Vote { slot, vr: round, vv: value.clone() });
                    fx.announce(Announce::DurableVote { node: self.id, slot, vr: round });
                }
                self.votes.insert(slot, Vote { vr: round, vv: value });
                fx.send(from, Msg::Phase2B { round, slot });
            }

            // Fast round proposal (Matchmaker Fast Paxos §7): the acceptor
            // votes for the *first* value proposed to it in the fast round,
            // reporting its vote to the round's coordinator (`round.proposer`)
            // so the coordinator can detect conflicts.
            Msg::FastPropose { round, value } => {
                if !self.fast {
                    return;
                }
                if self.seen_geq(round) {
                    fx.send(from, Msg::Nack { round, higher: self.round.unwrap() });
                    return;
                }
                // Slot 0: the fast variant is single-decree.
                let vote = match self.votes.get(&0) {
                    Some(v) if v.vr == round => {
                        // Already voted in this fast round: report the
                        // existing vote (do not change it).
                        v.clone()
                    }
                    _ => {
                        let raised = self.round != Some(round);
                        self.round = Some(round);
                        let v = Vote { vr: round, vv: value };
                        if self.storage.is_some() {
                            if raised {
                                self.persist(WalRecord::Promise { round });
                                fx.announce(Announce::DurablePromise { node: self.id, round });
                            }
                            self.persist(WalRecord::Vote {
                                slot: 0,
                                vr: round,
                                vv: v.vv.clone(),
                            });
                            fx.announce(Announce::DurableVote { node: self.id, slot: 0, vr: round });
                        }
                        self.votes.insert(0, v.clone());
                        v
                    }
                };
                fx.send(round.proposer, Msg::FastPhase2B { round: vote.vr, value: vote.vv });
            }

            // Read-lease renewal (DESIGN.md §Reads): ack while we have
            // promised no round higher than the lease's. Any newer
            // round's Phase 1 raises `self.round` first, so from that
            // point every renewal of the old round is nacked — the
            // quorum-intersection fence that kills a deposed leader's
            // lease within one refresh interval.
            Msg::LeaseRenew { round, seq } => {
                if self.seen_geq(round) {
                    fx.send(from, Msg::Nack { round, higher: self.round.unwrap() });
                    return;
                }
                let raised = self.round != Some(round);
                self.round = Some(round);
                if raised && self.storage.is_some() {
                    self.persist(WalRecord::Promise { round });
                    fx.announce(Announce::DurablePromise { node: self.id, round });
                }
                fx.send(from, Msg::LeaseRenewAck { round, seq });
            }

            // GC Scenario 3 bookkeeping: the leader certifies that the
            // prefix `< upto` is stored on f+1 replicas.
            Msg::PrefixPersisted { round, upto } => {
                if self.seen_geq(round) {
                    fx.send(from, Msg::Nack { round, higher: self.round.unwrap() });
                    return;
                }
                let raised = self.round != Some(round);
                self.round = Some(round);
                if raised && self.storage.is_some() {
                    self.persist(WalRecord::Promise { round });
                    fx.announce(Announce::DurablePromise { node: self.id, round });
                }
                if upto > self.chosen_watermark {
                    self.chosen_watermark = upto;
                    self.compact();
                    if self.storage.is_some() {
                        self.persist(WalRecord::Watermark { upto });
                        // The watermark retired the prefix everywhere:
                        // rewrite the log to the live set so disk usage
                        // tracks the in-memory footprint.
                        self.compact_storage();
                        fx.announce(Announce::AcceptorWatermark { node: self.id, upto });
                    }
                }
                fx.send(from, Msg::PrefixAck { round, upto: self.chosen_watermark });
            }

            _ => {}
        }
    }

    fn on_timer(&mut self, _now: Time, _timer: Timer, _fx: &mut Effects) {}

    fn role(&self) -> &'static str {
        "acceptor"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn state_repr(&self) -> Option<String> {
        // An acceptor's state is exactly Algorithm 2's (r, per-slot
        // votes) plus the chosen-prefix watermark; none of it is
        // time-valued. The durable log is a mirror of this state, not
        // additional state, so it is excluded.
        Some(format!(
            "acc r={:?} votes={:?} wm={} fast={}",
            self.round, self.votes, self.chosen_watermark, self.fast
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::Effects;

    fn r(epoch: u64, p: NodeId, s: u64) -> Round {
        Round { epoch, proposer: p, seq: s }
    }

    fn run(a: &mut Acceptor, from: NodeId, m: Msg) -> Vec<(NodeId, Msg)> {
        let mut fx = Effects::new();
        a.on_msg(0, from, m, &mut fx);
        fx.msgs
    }

    #[test]
    fn phase1_promise_and_report() {
        let mut a = Acceptor::new(1);
        // Vote first in round (0,0,0).
        let out = run(&mut a, 0, Msg::Phase2A { round: r(0, 0, 0), slot: 3, value: Value::Noop });
        assert_eq!(out[0].1, Msg::Phase2B { round: r(0, 0, 0), slot: 3 });

        // Phase1A in a higher round sees the vote.
        let out = run(&mut a, 5, Msg::Phase1A { round: r(1, 5, 0), from_slot: 0 });
        match &out[0].1 {
            Msg::Phase1B { round, votes, chosen_watermark } => {
                assert_eq!(*round, r(1, 5, 0));
                assert_eq!(*chosen_watermark, 0);
                assert_eq!(votes.len(), 1);
                assert_eq!(votes[0].slot, 3);
                assert_eq!(votes[0].vr, r(0, 0, 0));
            }
            other => panic!("expected Phase1B, got {other:?}"),
        }
    }

    #[test]
    fn stale_phase1a_nacked() {
        let mut a = Acceptor::new(1);
        run(&mut a, 0, Msg::Phase1A { round: r(2, 0, 0), from_slot: 0 });
        let out = run(&mut a, 9, Msg::Phase1A { round: r(1, 9, 0), from_slot: 0 });
        assert_eq!(out[0].1, Msg::Nack { round: r(1, 9, 0), higher: r(2, 0, 0) });
    }

    #[test]
    fn stale_phase2a_nacked_equal_allowed() {
        let mut a = Acceptor::new(1);
        run(&mut a, 0, Msg::Phase1A { round: r(3, 0, 0), from_slot: 0 });
        // Equal round: allowed (Algorithm 2 uses i >= r for Phase2A).
        let out = run(&mut a, 0, Msg::Phase2A { round: r(3, 0, 0), slot: 0, value: Value::Noop });
        assert_eq!(out[0].1, Msg::Phase2B { round: r(3, 0, 0), slot: 0 });
        // Lower round: nacked.
        let out = run(&mut a, 1, Msg::Phase2A { round: r(2, 1, 0), slot: 0, value: Value::Noop });
        assert!(matches!(out[0].1, Msg::Nack { .. }));
    }

    #[test]
    fn phase1b_respects_from_slot_and_watermark() {
        let mut a = Acceptor::new(1);
        for s in 0..6 {
            run(&mut a, 0, Msg::Phase2A { round: r(0, 0, 0), slot: s, value: Value::Noop });
        }
        run(&mut a, 0, Msg::PrefixPersisted { round: r(0, 0, 0), upto: 2 });
        let out = run(&mut a, 5, Msg::Phase1A { round: r(1, 5, 0), from_slot: 4 });
        match &out[0].1 {
            Msg::Phase1B { votes, chosen_watermark, .. } => {
                assert_eq!(*chosen_watermark, 2);
                let slots: Vec<Slot> = votes.iter().map(|v| v.slot).collect();
                assert_eq!(slots, vec![4, 5]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prefix_persisted_compacts() {
        let mut a = Acceptor::new(1);
        for s in 0..10 {
            run(&mut a, 0, Msg::Phase2A { round: r(0, 0, 0), slot: s, value: Value::Noop });
        }
        let out = run(&mut a, 0, Msg::PrefixPersisted { round: r(0, 0, 0), upto: 7 });
        assert_eq!(out[0].1, Msg::PrefixAck { round: r(0, 0, 0), upto: 7 });
        assert_eq!(a.votes.len(), 3);
        // Watermark never regresses.
        run(&mut a, 0, Msg::PrefixPersisted { round: r(0, 0, 0), upto: 3 });
        assert_eq!(a.chosen_watermark, 7);
    }

    #[test]
    fn lease_renewals_acked_until_higher_round_promised() {
        let mut a = Acceptor::new(1);
        let out = run(&mut a, 0, Msg::LeaseRenew { round: r(1, 0, 0), seq: 7 });
        assert_eq!(out[0].1, Msg::LeaseRenewAck { round: r(1, 0, 0), seq: 7 });
        // Equal-round renewals keep flowing.
        let out = run(&mut a, 0, Msg::LeaseRenew { round: r(1, 0, 0), seq: 8 });
        assert_eq!(out[0].1, Msg::LeaseRenewAck { round: r(1, 0, 0), seq: 8 });
        // A newer round's Phase 1 cuts the old leader's renewals off.
        run(&mut a, 5, Msg::Phase1A { round: r(2, 5, 0), from_slot: 0 });
        let out = run(&mut a, 0, Msg::LeaseRenew { round: r(1, 0, 0), seq: 9 });
        assert_eq!(out[0].1, Msg::Nack { round: r(1, 0, 0), higher: r(2, 5, 0) });
    }

    #[test]
    fn fast_round_first_value_wins() {
        let mut a = Acceptor::new_fast(1);
        let v1 = Value::Cmd(crate::msg::Command { client: 8, seq: 0, payload: vec![1] });
        let v2 = Value::Cmd(crate::msg::Command { client: 9, seq: 0, payload: vec![2] });
        let out = run(&mut a, 8, Msg::FastPropose { round: r(0, 0, 0), value: v1.clone() });
        assert_eq!(out[0].1, Msg::FastPhase2B { round: r(0, 0, 0), value: v1.clone() });
        // Second proposal in the same round: reports the original vote.
        let out = run(&mut a, 9, Msg::FastPropose { round: r(0, 0, 0), value: v2 });
        assert_eq!(out[0].1, Msg::FastPhase2B { round: r(0, 0, 0), value: v1 });
    }

    #[test]
    fn non_fast_acceptor_ignores_fast_propose() {
        let mut a = Acceptor::new(1);
        let out = run(&mut a, 8, Msg::FastPropose { round: r(0, 0, 0), value: Value::Noop });
        assert!(out.is_empty());
    }

    #[test]
    fn crash_recovery_restores_durable_state() {
        use crate::node::Announce;
        use crate::storage::MemStorage;
        let mut a = Acceptor::new(1);
        a.attach_storage(Box::new(MemStorage::new()));
        run(&mut a, 0, Msg::Phase1A { round: r(2, 0, 0), from_slot: 0 });
        for s in 0..5 {
            run(&mut a, 0, Msg::Phase2A { round: r(2, 0, 0), slot: s, value: Value::Noop });
        }
        run(&mut a, 0, Msg::PrefixPersisted { round: r(2, 0, 0), upto: 2 });
        // "kill -9": only the disk survives.
        let disk = a.take_storage().unwrap();
        let mut b = Acceptor::new(1);
        b.attach_storage(disk);
        let mut fx = Effects::new();
        b.recover(&mut fx);
        assert_eq!(b.round, Some(r(2, 0, 0)));
        assert_eq!(b.chosen_watermark, 2);
        assert_eq!(b.votes, a.votes);
        match fx.announces.last() {
            Some(Announce::AcceptorRecovered { node: 1, round, watermark: 2, votes }) => {
                assert_eq!(*round, Some(r(2, 0, 0)));
                assert_eq!(votes.len(), 3); // slots 2..5 survive the watermark
            }
            other => panic!("expected AcceptorRecovered, got {other:?}"),
        }
        // Restored and pre-crash state render identically.
        assert_eq!(a.state_repr(), b.state_repr());
    }

    #[test]
    fn durable_acks_announce_persistence() {
        use crate::node::Announce;
        use crate::storage::MemStorage;
        let mut a = Acceptor::new(1);
        a.attach_storage(Box::new(MemStorage::new()));
        let mut fx = Effects::new();
        a.on_msg(0, 0, Msg::Phase1A { round: r(1, 0, 0), from_slot: 0 }, &mut fx);
        assert!(matches!(
            fx.announces[..],
            [Announce::DurablePromise { node: 1, .. }]
        ));
        let mut fx = Effects::new();
        a.on_msg(
            0,
            0,
            Msg::Phase2A { round: r(1, 0, 0), slot: 4, value: Value::Noop },
            &mut fx,
        );
        assert!(matches!(
            fx.announces[..],
            [Announce::DurableVote { node: 1, slot: 4, .. }]
        ));
        // Without storage: no durability probes at all.
        let mut plain = Acceptor::new(2);
        let mut fx = Effects::new();
        plain.on_msg(0, 0, Msg::Phase1A { round: r(1, 0, 0), from_slot: 0 }, &mut fx);
        assert!(fx.announces.is_empty());
    }
}
