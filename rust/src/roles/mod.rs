//! Protocol roles, each a sans-io [`crate::node::Node`].
//!
//! * [`acceptor`] — classic (Flexible) Paxos acceptor, per-slot votes.
//! * [`matchmaker`] — the paper's contribution: configuration log, GC,
//!   stop/bootstrap reconfiguration, meta-Paxos acceptor duty (§3, §5, §6).
//! * [`leader`] — Matchmaker MultiPaxos leader: matchmaking, bulk Phase 1,
//!   steady-state Phase 2, reconfiguration with Phase-1 bypassing,
//!   GC driving, thriftiness, heartbeats (§4, §5).
//! * [`proposer`] — single-decree Matchmaker Paxos (Algorithm 3) and the
//!   Matchmaker Fast Paxos variant (§7, Algorithm 5).
//! * [`replica`] — state-machine replica: executes the chosen log in prefix
//!   order, replies to clients, acks prefixes for GC Scenario 3.
//! * [`client`] — workload client ([`crate::workload::WorkloadSpec`]-driven:
//!   closed-loop, pipelined, or open-loop) with latency recording.
//! * [`router`] — the sharded workload client: routes each key to its
//!   home consensus group by hash ([`router::shard_of`]), with an
//!   independent FIFO seq stream per group.
//! * [`sequencer`] — leader-side per-client FIFO admission for pipelined
//!   clients whose in-flight window the network may reorder.
//! * [`horizontal`] — baseline: MultiPaxos with horizontal (log-entry)
//!   reconfiguration and an α window (§7.2).

pub mod acceptor;
pub mod client;
pub mod horizontal;
pub mod leader;
pub mod matchmaker;
pub mod proposer;
pub mod replica;
pub mod router;
pub mod sequencer;

pub use acceptor::Acceptor;
pub use client::Client;
pub use horizontal::HorizontalLeader;
pub use leader::Leader;
pub use matchmaker::Matchmaker;
pub use proposer::{FastProposer, Proposer};
pub use replica::Replica;
pub use router::ShardClient;
pub use sequencer::ClientSequencer;
