//! Closed-loop workload clients (§8.1: "every client repeatedly proposes a
//! state machine command, waits to receive a response, and then immediately
//! proposes another command").
//!
//! Clients record `(completion_time, latency)` samples which the harness
//! turns into the paper's sliding-window latency/throughput series.

use crate::msg::{Command, Msg};
use crate::node::{Effects, Node, Timer};
use crate::{NodeId, Time};

/// A closed-loop client.
pub struct Client {
    pub id: NodeId,
    /// Proposers, in fallback order; `leader_hint` indexes into this list.
    pub proposers: Vec<NodeId>,
    pub leader_hint: usize,
    /// Payload for each command (paper: one-byte no-op).
    pub payload: Vec<u8>,
    /// Resend timeout if no reply arrives.
    pub resend_after: Time,
    /// Next sequence number to send.
    pub seq: u64,
    /// In-flight request: (seq, send_time).
    pub outstanding: Option<(u64, Time)>,
    /// Completed-request samples `(completion_time, latency_ns)`.
    pub samples: Vec<(Time, Time)>,
    /// Bumped on every (re)send; stale resend timers are ignored.
    generation: u64,
    /// Start issuing at this time (0 = immediately on start).
    pub start_at: Time,
    /// Stop issuing new requests after this time (u64::MAX = never).
    pub stop_at: Time,
}

impl Client {
    pub fn new(id: NodeId, proposers: Vec<NodeId>) -> Client {
        Client {
            id,
            proposers,
            leader_hint: 0,
            payload: vec![0u8],
            resend_after: 100 * crate::MS,
            seq: 0,
            outstanding: None,
            samples: Vec::new(),
            generation: 0,
            start_at: 0,
            stop_at: u64::MAX,
        }
    }

    fn leader(&self) -> NodeId {
        self.proposers[self.leader_hint % self.proposers.len()]
    }

    fn send_next(&mut self, now: Time, fx: &mut Effects) {
        if now >= self.stop_at {
            self.outstanding = None;
            return;
        }
        self.seq += 1;
        self.generation += 1;
        self.outstanding = Some((self.seq, now));
        let cmd = Command { client: self.id, seq: self.seq, payload: self.payload.clone() };
        fx.send(self.leader(), Msg::ClientRequest { cmd });
        fx.timer(
            self.resend_after,
            Timer::ClientResend { seq: self.seq, generation: self.generation },
        );
    }

    fn resend(&mut self, now: Time, fx: &mut Effects) {
        if let Some((seq, _sent)) = self.outstanding {
            let cmd = Command { client: self.id, seq, payload: self.payload.clone() };
            self.generation += 1;
            fx.send(self.leader(), Msg::ClientRequest { cmd });
            fx.timer(
                self.resend_after,
                Timer::ClientResend { seq, generation: self.generation },
            );
            let _ = now;
        }
    }
}

impl Node for Client {
    fn on_start(&mut self, now: Time, fx: &mut Effects) {
        if self.start_at > now {
            fx.timer(self.start_at - now, Timer::Wakeup { tag: 0 });
        } else {
            self.send_next(now, fx);
        }
    }

    fn on_msg(&mut self, now: Time, _from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::ClientReply { seq, .. } => {
                if let Some((out_seq, sent)) = self.outstanding {
                    if seq == out_seq {
                        self.samples.push((now, now - sent));
                        self.send_next(now, fx);
                    }
                    // Stale/duplicate replies (other replicas) are ignored.
                }
            }
            Msg::NotLeader { hint } => {
                if let Some(h) = hint {
                    if let Some(idx) = self.proposers.iter().position(|&p| p == h) {
                        self.leader_hint = idx;
                    }
                } else {
                    self.leader_hint = (self.leader_hint + 1) % self.proposers.len();
                }
                // Retry immediately against the new hint.
                self.resend(now, fx);
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, now: Time, timer: Timer, fx: &mut Effects) {
        match timer {
            Timer::ClientResend { seq, generation } => {
                // Only the most recently armed timer for the current
                // outstanding request is live; completed or re-sent
                // requests leave stale timers behind.
                if generation == self.generation
                    && matches!(self.outstanding, Some((s, _)) if s == seq)
                {
                    // Rotate the hint: the leader may have failed.
                    self.leader_hint = (self.leader_hint + 1) % self.proposers.len();
                    self.resend(now, fx);
                }
            }
            Timer::Wakeup { tag: 0 } => {
                if self.outstanding.is_none() {
                    self.send_next(now, fx);
                }
            }
            _ => {}
        }
    }

    fn role(&self) -> &'static str {
        "client"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reply(c: &mut Client, now: Time, seq: u64) -> Effects {
        let mut fx = Effects::new();
        c.on_msg(now, 0, Msg::ClientReply { seq, result: vec![] }, &mut fx);
        fx
    }

    #[test]
    fn closed_loop() {
        let mut c = Client::new(10, vec![0, 1]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(fx.msgs.len(), 1);
        assert!(matches!(fx.msgs[0].1, Msg::ClientRequest { .. }));
        assert_eq!(c.outstanding.unwrap().0, 1);

        // Reply at t=5ms: sample recorded, next request sent immediately.
        let fx = reply(&mut c, 5 * crate::MS, 1);
        assert_eq!(c.samples, vec![(5 * crate::MS, 5 * crate::MS)]);
        assert_eq!(c.outstanding.unwrap().0, 2);
        assert_eq!(fx.msgs.len(), 1);
    }

    #[test]
    fn stale_reply_ignored() {
        let mut c = Client::new(10, vec![0]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        reply(&mut c, 1, 1);
        // A second (duplicate) reply for seq 1 doesn't double-count.
        reply(&mut c, 2, 1);
        assert_eq!(c.samples.len(), 1);
        assert_eq!(c.outstanding.unwrap().0, 2);
    }

    #[test]
    fn not_leader_redirects() {
        let mut c = Client::new(10, vec![0, 1]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let mut fx2 = Effects::new();
        c.on_msg(1, 0, Msg::NotLeader { hint: Some(1) }, &mut fx2);
        assert_eq!(c.leader_hint, 1);
        // Resent to the new leader.
        assert_eq!(fx2.msgs[0].0, 1);
    }

    #[test]
    fn resend_timer_rotates_leader() {
        let mut c = Client::new(10, vec![0, 1]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let mut fx2 = Effects::new();
        c.on_timer(c.resend_after, Timer::ClientResend { seq: 1, generation: 1 }, &mut fx2);
        assert_eq!(c.leader_hint, 1);
        assert_eq!(fx2.msgs.len(), 1);
        // A stale-generation timer is a no-op (the resend bumped gen to 2).
        let mut fxg = Effects::new();
        c.on_timer(c.resend_after, Timer::ClientResend { seq: 1, generation: 1 }, &mut fxg);
        assert!(fxg.msgs.is_empty());
        // Stale resend timer (request already done) is a no-op.
        reply(&mut c, 1, 1);
        let mut fx3 = Effects::new();
        c.on_timer(2 * c.resend_after, Timer::ClientResend { seq: 1, generation: 2 }, &mut fx3);
        assert!(fx3.msgs.is_empty());
    }

    #[test]
    fn stop_at_halts_issuing() {
        let mut c = Client::new(10, vec![0]);
        c.stop_at = 10;
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        reply(&mut c, 20, 1);
        assert!(c.outstanding.is_none());
        assert_eq!(c.samples.len(), 1);
    }

    #[test]
    fn delayed_start() {
        let mut c = Client::new(10, vec![0]);
        c.start_at = 100;
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert!(fx.msgs.is_empty());
        assert_eq!(fx.timers.len(), 1);
        let mut fx2 = Effects::new();
        c.on_timer(100, Timer::Wakeup { tag: 0 }, &mut fx2);
        assert_eq!(fx2.msgs.len(), 1);
    }
}
