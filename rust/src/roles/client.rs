//! Workload clients, driven by a [`WorkloadSpec`].
//!
//! One role serves every workload mode (§8.1's closed loop, the pipelined
//! closed loop, and fixed-rate / deterministic-Poisson open loop):
//!
//! * **Closed loop** (`window = 1`): "every client repeatedly proposes a
//!   state machine command, waits to receive a response, and then
//!   immediately proposes another command" — the paper's client.
//! * **Pipelined** (`window = k`): up to `k` requests outstanding, each
//!   with its own resend timer; replies refill the window. Per-client
//!   FIFO order is preserved by the leader-side sequencer
//!   ([`crate::roles::sequencer`]) even when the network reorders the
//!   in-flight requests.
//! * **Open loop**: requests *arrive* on a timer (fixed interval or
//!   exponential gaps from the client's deterministic RNG), independent
//!   of completions, bounded by `max_in_flight`; excess arrivals queue
//!   client-side. Latency is measured from arrival, so queueing delay
//!   under overload is visible. `offered` vs `completed` counters feed
//!   the offered-load experiment (X4).
//!
//! Clients record `(completion_time, latency)` samples which the harness
//! turns into the paper's sliding-window latency/throughput series.

use crate::msg::{Command, Msg};
use crate::node::{Effects, Node, Timer};
use crate::util::Rng;
use crate::workload::{WorkloadMode, WorkloadSpec};
use crate::{GroupId, NodeId, Time, MS, US};
use std::collections::{BTreeMap, VecDeque};

/// `Timer::Wakeup` tag: delayed start (`WorkloadSpec::start_at`).
pub const TAG_START: u64 = 0;
/// `Timer::Wakeup` tag: open-loop arrival tick.
pub const TAG_ARRIVAL: u64 = 1;

/// Retry backoff cap: resend delays stop doubling at
/// `resend_after << BACKOFF_MAX_SHIFT` (32×).
pub const BACKOFF_MAX_SHIFT: u32 = 5;

/// Capped exponential backoff with deterministic jitter for retry timers
/// (satellite fix: fixed-interval resends re-fire at full rate forever,
/// so under overload every unacked request retries at line rate and
/// amplifies the overload — a retry storm). The jitter is a pure
/// [`crate::util::splitmix64`] hash of `(client, seq, attempt)`, NOT a
/// draw from the client's RNG: that stream feeds arrival processes and
/// read/write classification and must stay bit-identical with
/// pre-backoff builds.
pub(crate) fn backoff_delay(base: Time, id: NodeId, seq: u64, attempt: u32) -> Time {
    let capped = base.saturating_mul(1 << attempt.min(BACKOFF_MAX_SHIFT));
    let jitter_span = (base / 4).max(1);
    let h = crate::util::splitmix64(
        (id as u64) ^ seq.rotate_left(17) ^ ((attempt as u64) << 48) ^ 0xb0ff_5eed,
    );
    capped + h % jitter_span
}

/// One in-flight request.
#[derive(Clone, Copy, Debug)]
struct Outstanding {
    /// When the request entered the system (arrival time for open-loop
    /// requests that queued; send time otherwise). Latency is measured
    /// from here.
    issued_at: Time,
    /// Matches the most recently armed resend timer; stale timers from
    /// earlier (re)sends of this request carry older generations.
    generation: u64,
    /// Whether this operation is a read (carries the spec's read
    /// payload; recorded separately on completion). Reads normally ride
    /// the replica read path; with no known replicas they fall through
    /// the log like any command (the all-through-Phase-2 baseline).
    read: bool,
    /// Resend attempts so far (0 for a fresh request; drives the capped
    /// exponential backoff). "Reset on reply" falls out of removal: a
    /// reply removes the entry, so a later request starts at 0.
    attempt: u32,
}

/// A workload client (closed-loop, pipelined, or open-loop per its spec).
pub struct Client {
    /// This node's id (doubles as the `Command::client` identity).
    pub id: NodeId,
    /// The consensus group this client's requests target (0 in
    /// single-group deployments). Multi-group key-hash routing lives in
    /// [`crate::roles::router::ShardClient`]; this role drives exactly
    /// one group.
    pub group: GroupId,
    /// Proposers, in fallback order; `leader_hint` indexes into this list.
    pub proposers: Vec<NodeId>,
    /// Index of the proposer currently believed to be leader.
    pub leader_hint: usize,
    /// The group's replicas: linearizable-read targets. Empty (the
    /// default) routes read-classified requests through the log instead
    /// — the all-through-Phase-2 baseline. Wired by the harness.
    pub replicas: Vec<NodeId>,
    /// Rotation offset into `replicas` (bumped on read timeouts and
    /// `NotLeaseholder` redirects).
    pub replica_hint: usize,
    /// The workload this client runs.
    pub spec: WorkloadSpec,
    /// Completed-request samples `(completion_time, latency_ns)`.
    pub samples: Vec<(Time, Time)>,
    /// Requests generated: open-loop arrivals, or closed-loop sends.
    pub offered: u64,
    /// Requests completed (a reply was received).
    pub completed: u64,
    /// Requests dropped at the stop deadline after losing their replies
    /// (resends are bounded by `stop_at`), shed on `Busy` pushback
    /// (`shed_on_busy`), or dropped because the open-loop arrival queue
    /// hit its `queue_cap`.
    pub abandoned: u64,
    /// `Msg::Busy` pushbacks received (admission control; the harness
    /// derives per-group busy rates from this).
    pub busy_observed: u64,
    /// Policy on `Busy` pushback: `true` sheds the request (drop + count
    /// in `abandoned`), `false` (default) retries after the leader's
    /// `retry_after_us` hint. Wired by the harness from
    /// [`crate::config::AdmissionSpec::shed`].
    pub shed_on_busy: bool,
    /// Reads completed (subset of `completed`).
    pub reads_completed: u64,
    /// Completed write operations: `(issued_at, completed_at)`. With
    /// `write_issues` and `reads` this is the raw material for the
    /// linearizable-read checker ([`crate::metrics::check_counter_reads`]).
    pub writes: Vec<(Time, Time)>,
    /// Issue times of every write ever sent (including writes that
    /// never completed — an abandoned write may still execute, so the
    /// checker's upper bound must count it).
    pub write_issues: Vec<Time>,
    /// Completed reads: `(issued_at, completed_at, result)`.
    pub reads: Vec<(Time, Time, Vec<u8>)>,

    /// Payload for this client's commands (resolved from the spec once).
    payload: Vec<u8>,
    /// Payload for this client's read queries (resolved once).
    read_payload: Vec<u8>,
    /// Next sequence number to assign (first command is seq 1).
    next_seq: u64,
    /// In-flight requests by seq.
    outstanding: BTreeMap<u64, Outstanding>,
    /// Next read sequence number (reads live in their own seq space so
    /// they never perturb the leader-side FIFO sequencer).
    read_next_seq: u64,
    /// In-flight replica-path reads by read seq.
    read_outstanding: BTreeMap<u64, Outstanding>,
    /// Open-loop arrivals waiting for a free in-flight slot: `(arrival
    /// time, read?)`. Classification happens at arrival so the mix is
    /// arrival-deterministic, not drain-order-dependent.
    backlog: VecDeque<(Time, bool)>,
    /// Bumped on every (re)send; stale resend timers are ignored.
    generation: u64,
    /// Last time a `NotLeader` redirect re-sent the whole window (guards
    /// against a redirect storm when many in-flight requests hit a
    /// follower at once).
    last_redirect: Time,
    /// Last time a throttled redirect probed with the oldest request.
    last_probe: Time,
    /// Last time a `NotLeaseholder` redirect re-sent the read window.
    last_read_redirect: Time,
    /// Deterministic per-client RNG (Poisson inter-arrival gaps).
    rng: Rng,
}

impl Client {
    /// A client driving `spec` against the given proposers.
    pub fn new(id: NodeId, proposers: Vec<NodeId>, spec: WorkloadSpec) -> Client {
        let payload = spec.payload.bytes_for(id);
        let read_payload = spec.read_payload.bytes_for(id);
        Client {
            id,
            group: 0,
            proposers,
            leader_hint: 0,
            replicas: Vec::new(),
            replica_hint: 0,
            payload,
            read_payload,
            spec,
            samples: Vec::new(),
            offered: 0,
            completed: 0,
            abandoned: 0,
            busy_observed: 0,
            shed_on_busy: false,
            reads_completed: 0,
            writes: Vec::new(),
            write_issues: Vec::new(),
            reads: Vec::new(),
            next_seq: 1,
            outstanding: BTreeMap::new(),
            read_next_seq: 1,
            read_outstanding: BTreeMap::new(),
            backlog: VecDeque::new(),
            generation: 0,
            last_redirect: 0,
            last_probe: 0,
            last_read_redirect: 0,
            rng: Rng::new(0x9e3779b97f4a7c15 ^ id as u64),
        }
    }

    /// Number of requests currently on the wire (reads + writes: the
    /// spec's in-flight bound covers both).
    pub fn in_flight(&self) -> usize {
        self.outstanding.len() + self.read_outstanding.len()
    }

    fn leader(&self) -> NodeId {
        self.proposers[self.leader_hint % self.proposers.len()]
    }

    /// Oldest in-flight seq: everything below it has been acknowledged to
    /// this client, which lets the leader's sequencer retire state and
    /// initialize ordering mid-stream (e.g. after a leader change).
    fn lowest_outstanding(&self) -> u64 {
        self.outstanding.keys().next().copied().unwrap_or(self.next_seq)
    }

    /// Draw the read/write classification for the next request. Skips
    /// the RNG entirely at `read_fraction == 0`, so all-write runs stay
    /// bit-identical with pre-read builds.
    fn classify(&mut self) -> bool {
        self.spec.read_fraction > 0.0 && self.rng.next_f64() < self.spec.read_fraction
    }

    /// Route one new operation: reads go to a replica when the replica
    /// set is known, else everything rides the log through the leader.
    fn dispatch(&mut self, read: bool, issued_at: Time, now: Time, fx: &mut Effects) {
        if read && !self.replicas.is_empty() {
            self.send_read(issued_at, now, fx);
        } else {
            self.send_request(read, issued_at, now, fx);
        }
    }

    /// Issue a brand-new request through the log. `issued_at` is the
    /// arrival time the latency clock starts from (≤ `now` for
    /// backlogged open-loop work).
    fn send_request(&mut self, read: bool, issued_at: Time, _now: Time, fx: &mut Effects) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.generation += 1;
        self.outstanding
            .insert(seq, Outstanding { issued_at, generation: self.generation, read, attempt: 0 });
        let payload = if read { self.read_payload.clone() } else { self.payload.clone() };
        if !read {
            self.write_issues.push(issued_at);
        }
        let cmd = Command { client: self.id, seq, payload };
        let lowest = self.lowest_outstanding();
        fx.send(self.leader(), Msg::ClientRequest { group: self.group, cmd, lowest });
        fx.timer(
            self.spec.resend_after,
            Timer::ClientResend { seq, generation: self.generation },
        );
    }

    /// Issue a brand-new linearizable read to a replica (reads spread
    /// across the replica set by seq, shifted by the rotation hint).
    fn send_read(&mut self, issued_at: Time, _now: Time, fx: &mut Effects) {
        let seq = self.read_next_seq;
        self.read_next_seq += 1;
        self.generation += 1;
        self.read_outstanding.insert(
            seq,
            Outstanding { issued_at, generation: self.generation, read: true, attempt: 0 },
        );
        let n = self.replicas.len();
        let target = self.replicas[(seq as usize + self.id as usize + self.replica_hint) % n];
        fx.send(
            target,
            Msg::Read { group: self.group, seq, payload: self.read_payload.clone() },
        );
        fx.timer(
            self.spec.resend_after,
            Timer::ReadResend { seq, generation: self.generation },
        );
    }

    /// Re-send one in-flight request, bounded by the stop deadline: a
    /// request whose replies keep getting lost is abandoned once `now`
    /// passes `stop_at` instead of being retried forever. Each resend
    /// backs the next timer off exponentially (capped, jittered) so a
    /// saturated leader sees a shrinking — not constant — retry rate.
    fn resend_one(&mut self, seq: u64, now: Time, fx: &mut Effects) {
        if now >= self.spec.stop_at {
            if self.outstanding.remove(&seq).is_some() {
                self.abandoned += 1;
            }
            return;
        }
        self.generation += 1;
        let generation = self.generation;
        let Some(o) = self.outstanding.get_mut(&seq) else {
            return;
        };
        o.generation = generation;
        o.attempt = o.attempt.saturating_add(1);
        let attempt = o.attempt;
        let payload = if o.read { self.read_payload.clone() } else { self.payload.clone() };
        let cmd = Command { client: self.id, seq, payload };
        let lowest = self.lowest_outstanding();
        fx.send(self.leader(), Msg::ClientRequest { group: self.group, cmd, lowest });
        let delay = backoff_delay(self.spec.resend_after, self.id, seq, attempt);
        fx.timer(delay, Timer::ClientResend { seq, generation });
    }

    /// Re-send one in-flight read to the (rotated) replica target.
    fn resend_read_one(&mut self, seq: u64, now: Time, fx: &mut Effects) {
        if now >= self.spec.stop_at {
            if self.read_outstanding.remove(&seq).is_some() {
                self.abandoned += 1;
            }
            return;
        }
        self.generation += 1;
        let generation = self.generation;
        let Some(o) = self.read_outstanding.get_mut(&seq) else {
            return;
        };
        o.generation = generation;
        o.attempt = o.attempt.saturating_add(1);
        let attempt = o.attempt;
        let n = self.replicas.len();
        if n == 0 {
            return;
        }
        let target = self.replicas[(seq as usize + self.id as usize + self.replica_hint) % n];
        fx.send(
            target,
            Msg::Read { group: self.group, seq, payload: self.read_payload.clone() },
        );
        let delay = backoff_delay(self.spec.resend_after, self.id, seq, attempt);
        fx.timer(delay, Timer::ReadResend { seq, generation });
    }

    /// Closed-loop refill: keep `window` requests outstanding until the
    /// stop deadline.
    fn fill_window(&mut self, now: Time, fx: &mut Effects) {
        let WorkloadMode::ClosedLoop { window } = self.spec.mode else {
            return;
        };
        while self.in_flight() < window && now < self.spec.stop_at {
            self.offered += 1;
            let read = self.classify();
            self.dispatch(read, now, now, fx);
        }
    }

    /// One open-loop arrival at `now`; schedules the next tick.
    fn on_arrival(&mut self, now: Time, fx: &mut Effects) {
        let WorkloadMode::OpenLoop { interval, poisson, max_in_flight, queue_cap } =
            self.spec.mode
        else {
            return;
        };
        if now >= self.spec.stop_at {
            return; // stop the arrival chain
        }
        self.offered += 1;
        let read = self.classify();
        if self.in_flight() < max_in_flight {
            self.dispatch(read, now, now, fx);
        } else if self.backlog.len() < queue_cap {
            self.backlog.push_back((now, read));
        } else {
            // Queue bound (satellite fix): past saturation the arrival
            // backlog would otherwise grow without limit; shed the
            // arrival instead and account for it (offered = completed +
            // abandoned + in-flight + queued still holds).
            self.abandoned += 1;
        }
        let gap = if poisson {
            // Exponential gap with mean `interval`, from the per-client
            // deterministic stream.
            let u = self.rng.next_f64();
            ((-(1.0 - u).ln()) * interval as f64) as Time
        } else {
            interval
        };
        fx.timer(gap.max(1), Timer::Wakeup { tag: TAG_ARRIVAL });
    }

    /// A completion freed an in-flight slot: refill the closed-loop
    /// window, or drain one backlogged open-loop arrival (abandoning
    /// the backlog past the stop deadline, keeping offered = completed
    /// + abandoned + in-flight).
    fn refill(&mut self, now: Time, fx: &mut Effects) {
        match self.spec.mode {
            WorkloadMode::ClosedLoop { .. } => self.fill_window(now, fx),
            WorkloadMode::OpenLoop { .. } => {
                if now >= self.spec.stop_at {
                    self.abandoned += self.backlog.len() as u64;
                    self.backlog.clear();
                } else if let Some((arrived, read)) = self.backlog.pop_front() {
                    self.dispatch(read, arrived, now, fx);
                }
            }
        }
    }

    /// Start generating work (at start time, or immediately).
    fn begin(&mut self, now: Time, fx: &mut Effects) {
        match self.spec.mode {
            WorkloadMode::ClosedLoop { .. } => self.fill_window(now, fx),
            WorkloadMode::OpenLoop { .. } => self.on_arrival(now, fx),
        }
    }
}

impl Node for Client {
    fn on_start(&mut self, now: Time, fx: &mut Effects) {
        if self.spec.start_at > now {
            fx.timer(self.spec.start_at - now, Timer::Wakeup { tag: TAG_START });
        } else {
            self.begin(now, fx);
        }
    }

    fn on_msg(&mut self, now: Time, _from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::ClientReply { seq, result, .. } => {
                let Some(o) = self.outstanding.remove(&seq) else {
                    return; // stale/duplicate reply (other replicas)
                };
                self.samples.push((now, now - o.issued_at));
                self.completed += 1;
                if o.read {
                    // Baseline path: a read that rode through the log.
                    self.reads_completed += 1;
                    self.reads.push((o.issued_at, now, result));
                } else {
                    self.writes.push((o.issued_at, now));
                }
                self.refill(now, fx);
            }
            Msg::ReadReply { seq, result, .. } => {
                let Some(o) = self.read_outstanding.remove(&seq) else {
                    return; // stale/duplicate reply
                };
                self.samples.push((now, now - o.issued_at));
                self.completed += 1;
                self.reads_completed += 1;
                self.reads.push((o.issued_at, now, result));
                self.refill(now, fx);
            }
            Msg::Busy { seq, retry_after_us, .. } => {
                // Admission pushback (DESIGN.md §Overload): the leader
                // dropped this request *without sequencer side effects*,
                // so it is safe either to retry it later (it will be
                // admitted in FIFO position like a first attempt) or to
                // shed it (it never executed and never will).
                if !self.outstanding.contains_key(&seq) {
                    return; // stale Busy for a request that since completed
                }
                self.busy_observed += 1;
                fx.announce(crate::node::Announce::BusyObserved { client: self.id, seq });
                if self.shed_on_busy {
                    self.outstanding.remove(&seq);
                    self.abandoned += 1;
                    self.refill(now, fx);
                } else {
                    // Delayed retry: the leader's hint is the backoff
                    // base, so the first pushback waits ~retry_after_us
                    // and repeated pushback widens the gap (capped,
                    // jittered). Bumping the generation invalidates the
                    // resend timer armed at send time, so pushback
                    // *replaces* the blind resend instead of racing it.
                    self.generation += 1;
                    let generation = self.generation;
                    let o = self.outstanding.get_mut(&seq).expect("checked above");
                    o.generation = generation;
                    o.attempt = o.attempt.saturating_add(1);
                    let attempt = o.attempt;
                    let hint = retry_after_us.max(1) * US;
                    let delay = backoff_delay(hint, self.id, seq, attempt.saturating_sub(1));
                    fx.timer(delay, Timer::ClientResend { seq, generation });
                }
            }
            Msg::NotLeaseholder { .. } => {
                // The replica can't serve reads right now: rotate to the
                // next one and re-send the read window, at most once per
                // millisecond (mirrors the NotLeader throttle).
                self.replica_hint = self.replica_hint.wrapping_add(1);
                if now.saturating_sub(self.last_read_redirect) >= MS
                    || self.last_read_redirect == 0
                {
                    self.last_read_redirect = now.max(1);
                    let seqs: Vec<u64> = self.read_outstanding.keys().copied().collect();
                    for seq in seqs {
                        self.resend_read_one(seq, now, fx);
                    }
                }
            }
            Msg::NotLeader { hint, .. } => {
                if let Some(h) = hint {
                    if let Some(idx) = self.proposers.iter().position(|&p| p == h) {
                        self.leader_hint = idx;
                    }
                } else {
                    self.leader_hint = (self.leader_hint + 1) % self.proposers.len();
                }
                // Re-send the whole window against the new hint, at most
                // once per millisecond: each in-flight request triggers
                // its own NotLeader reply, and re-sending all of them for
                // each would be quadratic in the window. Inside the
                // throttle window, still re-send the oldest request so
                // the redirect ping-pong keeps probing until a leader
                // emerges (otherwise a mid-election redirect would leave
                // nothing in flight until the 100 ms resend timer).
                if now.saturating_sub(self.last_redirect) >= MS || self.last_redirect == 0 {
                    self.last_redirect = now.max(1);
                    let seqs: Vec<u64> = self.outstanding.keys().copied().collect();
                    for seq in seqs {
                        self.resend_one(seq, now, fx);
                    }
                } else if now.saturating_sub(self.last_probe) >= 100 * US {
                    // One RTT-scale probe, not one per NotLeader reply: a
                    // window of k requests bouncing off a follower would
                    // otherwise turn into k duplicate probes per round.
                    self.last_probe = now;
                    if let Some(&oldest) = self.outstanding.keys().next() {
                        self.resend_one(oldest, now, fx);
                    }
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, now: Time, timer: Timer, fx: &mut Effects) {
        match timer {
            Timer::ClientResend { seq, generation } => {
                // Only the most recently armed timer for a live request
                // counts; completed or re-sent requests leave stale
                // timers behind.
                let live = self
                    .outstanding
                    .get(&seq)
                    .map_or(false, |o| o.generation == generation);
                if live {
                    // The leader may have failed: rotate the hint, but
                    // only when the *oldest* request times out, so a
                    // burst of per-request timeouts rotates once.
                    if self.lowest_outstanding() == seq {
                        self.leader_hint = (self.leader_hint + 1) % self.proposers.len();
                    }
                    self.resend_one(seq, now, fx);
                }
            }
            Timer::ReadResend { seq, generation } => {
                let live = self
                    .read_outstanding
                    .get(&seq)
                    .map_or(false, |o| o.generation == generation);
                if live {
                    // The target replica may be down or leaderless:
                    // rotate, but only on the oldest read's timeout so a
                    // burst rotates once.
                    if self.read_outstanding.keys().next() == Some(&seq) {
                        self.replica_hint = self.replica_hint.wrapping_add(1);
                    }
                    self.resend_read_one(seq, now, fx);
                }
            }
            Timer::Wakeup { tag: TAG_START } => {
                self.begin(now, fx);
            }
            Timer::Wakeup { tag: TAG_ARRIVAL } => {
                self.on_arrival(now, fx);
            }
            Timer::Wakeup { tag } => {
                // Every wakeup tag must be routed explicitly above.
                debug_assert!(false, "client {}: unknown wakeup tag {tag}", self.id);
            }
            _ => {}
        }
    }

    fn role(&self) -> &'static str {
        "client"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;
    use crate::SEC;

    fn reply(c: &mut Client, now: Time, seq: u64) -> Effects {
        let mut fx = Effects::new();
        c.on_msg(now, 0, Msg::ClientReply { group: 0, seq, result: vec![] }, &mut fx);
        fx
    }

    fn sent_seqs(fx: &Effects) -> Vec<u64> {
        fx.msgs
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::ClientRequest { cmd, .. } => Some(cmd.seq),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn closed_loop() {
        let mut c = Client::new(10, vec![0, 1], WorkloadSpec::closed_loop());
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(sent_seqs(&fx), vec![1]);
        assert_eq!(c.in_flight(), 1);

        // Reply at t=5ms: sample recorded, next request sent immediately.
        let fx = reply(&mut c, 5 * MS, 1);
        assert_eq!(c.samples, vec![(5 * MS, 5 * MS)]);
        assert_eq!(sent_seqs(&fx), vec![2]);
        assert_eq!((c.offered, c.completed), (2, 1));
    }

    #[test]
    fn pipelined_window_stays_full() {
        let mut c = Client::new(10, vec![0], WorkloadSpec::pipelined(3));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(sent_seqs(&fx), vec![1, 2, 3]);
        assert_eq!(c.in_flight(), 3);
        // Each reply frees one slot and triggers exactly one new send.
        let fx = reply(&mut c, MS, 1);
        assert_eq!(sent_seqs(&fx), vec![4]);
        assert_eq!(c.in_flight(), 3);
        // Out-of-order reply (seq 3 before 2) still refills.
        let fx = reply(&mut c, 2 * MS, 3);
        assert_eq!(sent_seqs(&fx), vec![5]);
        let outstanding: Vec<u64> = c.outstanding.keys().copied().collect();
        assert_eq!(outstanding, vec![2, 4, 5]);
    }

    #[test]
    fn requests_carry_lowest_outstanding() {
        let mut c = Client::new(10, vec![0], WorkloadSpec::pipelined(2));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let lowests: Vec<u64> = fx
            .msgs
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::ClientRequest { lowest, .. } => Some(*lowest),
                _ => None,
            })
            .collect();
        assert_eq!(lowests, vec![1, 1]);
        // After seq 1 completes, new requests advertise lowest = 2.
        let fx = reply(&mut c, MS, 1);
        match &fx.msgs[0].1 {
            Msg::ClientRequest { cmd, lowest, .. } => {
                assert_eq!(cmd.seq, 3);
                assert_eq!(*lowest, 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn open_loop_arrivals_independent_of_replies() {
        let mut c = Client::new(10, vec![0], WorkloadSpec::open_loop(100.0)); // 10 ms gap
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        // First arrival sends immediately and schedules the next tick.
        assert_eq!(sent_seqs(&fx), vec![1]);
        let ticks: Vec<Time> = fx
            .timers
            .iter()
            .filter_map(|(d, t)| {
                matches!(t, Timer::Wakeup { tag: TAG_ARRIVAL }).then_some(*d)
            })
            .collect();
        assert_eq!(ticks, vec![10 * MS]);
        // Two more arrivals with no replies: requests keep flowing.
        let mut fx2 = Effects::new();
        c.on_timer(10 * MS, Timer::Wakeup { tag: TAG_ARRIVAL }, &mut fx2);
        c.on_timer(20 * MS, Timer::Wakeup { tag: TAG_ARRIVAL }, &mut fx2);
        assert_eq!(sent_seqs(&fx2), vec![2, 3]);
        assert_eq!(c.offered, 3);
        assert_eq!(c.in_flight(), 3);
    }

    #[test]
    fn open_loop_bounds_in_flight_and_queues() {
        let spec = WorkloadSpec::open_loop(1000.0).max_in_flight(2);
        let mut c = Client::new(10, vec![0], spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let mut fx2 = Effects::new();
        c.on_timer(MS, Timer::Wakeup { tag: TAG_ARRIVAL }, &mut fx2);
        c.on_timer(2 * MS, Timer::Wakeup { tag: TAG_ARRIVAL }, &mut fx2);
        // Third arrival queues instead of sending.
        assert_eq!(sent_seqs(&fx2), vec![2]);
        assert_eq!(c.in_flight(), 2);
        assert_eq!(c.backlog.len(), 1);
        assert_eq!(c.offered, 3);
        // A reply drains the backlog; latency runs from the 2 ms arrival.
        let fx3 = reply(&mut c, 5 * MS, 1);
        assert_eq!(sent_seqs(&fx3), vec![3]);
        assert!(c.backlog.is_empty());
        let o = c.outstanding.get(&3).unwrap();
        assert_eq!(o.issued_at, 2 * MS);
    }

    #[test]
    fn poisson_arrivals_are_deterministic() {
        let gaps = |id: NodeId| -> Vec<Time> {
            let mut c = Client::new(id, vec![0], WorkloadSpec::open_loop_poisson(1000.0));
            let mut out = Vec::new();
            let mut now = 0;
            for _ in 0..5 {
                let mut fx = Effects::new();
                c.on_arrival(now, &mut fx);
                let (d, _) = fx
                    .timers
                    .iter()
                    .find(|(_, t)| matches!(t, Timer::Wakeup { tag: TAG_ARRIVAL }))
                    .expect("next tick scheduled");
                out.push(*d);
                now += d;
            }
            out
        };
        assert_eq!(gaps(5), gaps(5));
        assert_ne!(gaps(5), gaps(6)); // different clients, different schedules
    }

    #[test]
    fn resend_bounded_by_stop_at() {
        // Regression (satellite fix): a request lost after the stop
        // deadline must be abandoned, not retried forever.
        let spec = WorkloadSpec::closed_loop().stop_at(10 * MS);
        let mut c = Client::new(10, vec![0], spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(c.in_flight(), 1);
        // The reply never arrives; the resend timer fires after stop_at.
        let mut fx2 = Effects::new();
        c.on_timer(100 * MS, Timer::ClientResend { seq: 1, generation: 1 }, &mut fx2);
        assert!(fx2.msgs.is_empty(), "no resend past the stop deadline");
        assert!(fx2.timers.is_empty(), "no timer re-armed past the stop deadline");
        assert_eq!(c.in_flight(), 0);
        assert_eq!(c.abandoned, 1);
    }

    #[test]
    fn resend_before_stop_still_retries() {
        let spec = WorkloadSpec::closed_loop().stop_at(SEC);
        let mut c = Client::new(10, vec![0, 1], spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let mut fx2 = Effects::new();
        c.on_timer(100 * MS, Timer::ClientResend { seq: 1, generation: 1 }, &mut fx2);
        assert_eq!(sent_seqs(&fx2), vec![1]);
        // Oldest-request timeout rotates the leader hint.
        assert_eq!(c.leader_hint, 1);
        // A stale-generation timer is a no-op (the resend bumped the gen).
        let mut fx3 = Effects::new();
        c.on_timer(200 * MS, Timer::ClientResend { seq: 1, generation: 1 }, &mut fx3);
        assert!(fx3.msgs.is_empty());
        // Completed request: its timer is a no-op.
        reply(&mut c, 250 * MS, 1);
        let mut fx4 = Effects::new();
        c.on_timer(300 * MS, Timer::ClientResend { seq: 1, generation: 2 }, &mut fx4);
        assert!(!sent_seqs(&fx4).contains(&1));
    }

    fn read_mix_client(replicas: Vec<NodeId>) -> Client {
        let spec = WorkloadSpec::pipelined(4).read_fraction(1.0).read_payload(vec![9]);
        let mut c = Client::new(10, vec![0, 1], spec);
        c.replicas = replicas;
        c
    }

    fn sent_reads(fx: &Effects) -> Vec<(NodeId, u64)> {
        fx.msgs
            .iter()
            .filter_map(|(to, m)| match m {
                Msg::Read { seq, .. } => Some((*to, *seq)),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn reads_route_to_replicas_with_own_seq_space() {
        let mut c = read_mix_client(vec![20, 21, 22]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        // read_fraction 1.0: the whole window is reads, to replicas.
        let reads = sent_reads(&fx);
        assert_eq!(reads.len(), 4);
        assert!(sent_seqs(&fx).is_empty(), "no ClientRequests in an all-read mix");
        assert_eq!(c.in_flight(), 4);
        // Read seqs are 1..=4 in their own space, spread over replicas.
        let seqs: Vec<u64> = reads.iter().map(|r| r.1).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4]);
        assert!(reads.iter().all(|(to, _)| (20..=22).contains(to)));
        // A ReadReply completes, records, and refills the window.
        let mut fx2 = Effects::new();
        c.on_msg(
            MS,
            20,
            Msg::ReadReply { group: 0, seq: 1, result: vec![7] },
            &mut fx2,
        );
        assert_eq!(c.completed, 1);
        assert_eq!(c.reads_completed, 1);
        assert_eq!(c.reads, vec![(0, MS, vec![7])]);
        assert_eq!(c.in_flight(), 4, "window refilled");
        assert_eq!(sent_reads(&fx2).len(), 1);
    }

    #[test]
    fn reads_without_replicas_ride_the_log() {
        // The all-through-Phase-2 baseline: no replica set, so the read
        // payload goes through the leader as an ordinary command and
        // the reply is recorded as a read.
        let mut c = read_mix_client(vec![]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert!(sent_reads(&fx).is_empty());
        assert_eq!(sent_seqs(&fx), vec![1, 2, 3, 4]);
        for (_, m) in &fx.msgs {
            if let Msg::ClientRequest { cmd, .. } = m {
                assert_eq!(cmd.payload, vec![9], "read payload rides the log");
            }
        }
        let fx2 = reply(&mut c, MS, 1);
        assert_eq!(c.reads_completed, 1);
        assert_eq!(c.reads.len(), 1);
        assert!(c.writes.is_empty());
        assert_eq!(sent_seqs(&fx2).len(), 1);
    }

    #[test]
    fn mixed_workload_records_writes_and_write_issues() {
        let spec = WorkloadSpec::pipelined(32).read_fraction(0.5);
        let mut c = Client::new(10, vec![0], spec);
        c.replicas = vec![20, 21, 22];
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let n_reads = sent_reads(&fx).len();
        let n_writes = sent_seqs(&fx).len();
        assert_eq!(n_reads + n_writes, 32);
        assert!(n_reads > 0 && n_writes > 0, "seeded mix covers both kinds");
        assert_eq!(c.write_issues.len(), n_writes);
        // Completing a write records (issued, completed).
        if let Some(&wseq) = c.outstanding.keys().next() {
            reply(&mut c, 2 * MS, wseq);
            assert_eq!(c.writes.len(), 1);
            assert_eq!(c.writes[0].1, 2 * MS);
        }
    }

    #[test]
    fn read_resend_rotates_replica_and_respects_stop() {
        let spec = WorkloadSpec::pipelined(1)
            .read_fraction(1.0)
            .stop_at(crate::SEC);
        let mut c = Client::new(10, vec![0], spec);
        c.replicas = vec![20, 21];
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let first_target = sent_reads(&fx)[0].0;
        // Timeout of the oldest read rotates the replica hint.
        let mut fx2 = Effects::new();
        c.on_timer(100 * MS, Timer::ReadResend { seq: 1, generation: 1 }, &mut fx2);
        let second = sent_reads(&fx2);
        assert_eq!(second.len(), 1);
        assert_ne!(second[0].0, first_target, "resend rotated to the other replica");
        // Stale generation: no-op.
        let mut fx3 = Effects::new();
        c.on_timer(200 * MS, Timer::ReadResend { seq: 1, generation: 1 }, &mut fx3);
        assert!(sent_reads(&fx3).is_empty());
        // Past stop_at: abandoned, not retried.
        let gen = c.read_outstanding[&1].generation;
        let mut fx4 = Effects::new();
        c.on_timer(2 * crate::SEC, Timer::ReadResend { seq: 1, generation: gen }, &mut fx4);
        assert!(sent_reads(&fx4).is_empty());
        assert_eq!(c.abandoned, 1);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn not_leaseholder_redirects_read_window() {
        let mut c = read_mix_client(vec![20, 21]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let before = c.replica_hint;
        let mut fx2 = Effects::new();
        c.on_msg(MS, 20, Msg::NotLeaseholder { group: 0, hint: None }, &mut fx2);
        assert_eq!(c.replica_hint, before + 1);
        assert_eq!(sent_reads(&fx2).len(), 4, "whole read window re-sent");
        // A second redirect inside the throttle window only rotates.
        let mut fx3 = Effects::new();
        c.on_msg(MS + 1, 21, Msg::NotLeaseholder { group: 0, hint: None }, &mut fx3);
        assert!(sent_reads(&fx3).is_empty());
    }

    #[test]
    fn stale_reply_ignored() {
        let mut c = Client::new(10, vec![0], WorkloadSpec::closed_loop());
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        reply(&mut c, 1, 1);
        // A second (duplicate) reply for seq 1 doesn't double-count.
        reply(&mut c, 2, 1);
        assert_eq!(c.samples.len(), 1);
        assert_eq!(c.completed, 1);
    }

    #[test]
    fn not_leader_redirects_whole_window() {
        let mut c = Client::new(10, vec![0, 1], WorkloadSpec::pipelined(2));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let mut fx2 = Effects::new();
        c.on_msg(MS, 0, Msg::NotLeader { group: 0, hint: Some(1) }, &mut fx2);
        assert_eq!(c.leader_hint, 1);
        // Both in-flight requests re-sent to the new leader.
        assert_eq!(sent_seqs(&fx2), vec![1, 2]);
        assert!(fx2.msgs.iter().all(|(to, _)| *to == 1));
        // A second NotLeader within 1 ms is throttled down to a single
        // probe of the oldest request (not the whole window again).
        let mut fx3 = Effects::new();
        c.on_msg(MS + 1, 1, Msg::NotLeader { group: 0, hint: Some(0) }, &mut fx3);
        assert_eq!(sent_seqs(&fx3), vec![1]);
    }

    #[test]
    fn stop_at_halts_issuing() {
        let spec = WorkloadSpec::closed_loop().stop_at(10);
        let mut c = Client::new(10, vec![0], spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        reply(&mut c, 20, 1);
        assert_eq!(c.in_flight(), 0);
        assert_eq!(c.samples.len(), 1);
    }

    #[test]
    fn delayed_start() {
        let spec = WorkloadSpec::closed_loop().start_at(100);
        let mut c = Client::new(10, vec![0], spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert!(fx.msgs.is_empty());
        assert_eq!(fx.timers.len(), 1);
        let mut fx2 = Effects::new();
        c.on_timer(100, Timer::Wakeup { tag: TAG_START }, &mut fx2);
        assert_eq!(sent_seqs(&fx2), vec![1]);
    }

    #[test]
    #[should_panic(expected = "unknown wakeup tag")]
    fn unknown_wakeup_tag_asserts() {
        let mut c = Client::new(10, vec![0], WorkloadSpec::closed_loop());
        let mut fx = Effects::new();
        c.on_timer(0, Timer::Wakeup { tag: 99 }, &mut fx);
    }

    // ---- Overload control (DESIGN.md §Overload) ----

    fn next_resend(fx: &Effects) -> Option<(Time, Timer)> {
        fx.timers
            .iter()
            .find(|(_, t)| matches!(t, Timer::ClientResend { .. }))
            .map(|&(d, t)| (d, t))
    }

    #[test]
    fn resend_backoff_bounds_retry_traffic() {
        // Regression (satellite fix — retry storm): with the leader
        // saturated and never answering, a fixed 100 ms resend interval
        // would fire ~100 resends in 10 virtual seconds. Capped
        // exponential backoff keeps it to a handful.
        let spec = WorkloadSpec::closed_loop().stop_at(100 * SEC);
        let mut c = Client::new(10, vec![0], spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(sent_seqs(&fx), vec![1]);
        let (mut delay, mut timer) = next_resend(&fx).unwrap();
        let mut now = 0;
        let mut resends = 0u32;
        while now + delay <= 10 * SEC {
            now += delay;
            let mut fx2 = Effects::new();
            c.on_timer(now, timer, &mut fx2);
            resends += sent_seqs(&fx2).len() as u32;
            match next_resend(&fx2) {
                Some((d, t)) => (delay, timer) = (d, t),
                None => break,
            }
        }
        assert!((1..=12).contains(&resends), "retry storm: {resends} resends in 10 s");
        // The schedule saturates at the 32× cap (+ bounded jitter).
        let base = c.spec.resend_after;
        assert!(delay >= 32 * base && delay < 32 * base + base / 4, "uncapped delay {delay}");
        // The request is still alive — backoff delays, it never drops.
        assert_eq!(c.in_flight(), 1);
        assert_eq!(c.abandoned, 0);
    }

    #[test]
    fn backoff_jitter_is_deterministic_and_decorrelated() {
        let base = 100 * MS;
        // Same (client, seq, attempt): identical delay (replayable runs).
        assert_eq!(backoff_delay(base, 1, 7, 3), backoff_delay(base, 1, 7, 3));
        // Different clients desynchronize (no thundering herd).
        assert_ne!(backoff_delay(base, 1, 7, 3), backoff_delay(base, 2, 7, 3));
        // Cap respected far past the shift limit.
        let d = backoff_delay(base, 1, 7, 40);
        assert!(d >= 32 * base && d < 32 * base + base / 4);
    }

    #[test]
    fn busy_shed_drops_and_counts() {
        let mut c = Client::new(10, vec![0], WorkloadSpec::pipelined(2));
        c.shed_on_busy = true;
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(c.in_flight(), 2);
        let mut fx2 = Effects::new();
        c.on_msg(MS, 0, Msg::Busy { group: 0, seq: 1, retry_after_us: 1_000 }, &mut fx2);
        assert_eq!((c.busy_observed, c.abandoned), (1, 1));
        // The freed slot refills with a NEW seq; the shed seq is gone
        // and later requests advertise lowest = 2 (the leader never saw
        // seq 1, so nothing can be reordered around it).
        assert_eq!(sent_seqs(&fx2), vec![3]);
        assert!(!c.outstanding.contains_key(&1));
        assert_eq!(c.lowest_outstanding(), 2);
        // A stale Busy for the shed seq is a no-op.
        let mut fx3 = Effects::new();
        c.on_msg(2 * MS, 0, Msg::Busy { group: 0, seq: 1, retry_after_us: 1_000 }, &mut fx3);
        assert_eq!(c.busy_observed, 1);
        assert!(fx3.msgs.is_empty() && fx3.timers.is_empty());
    }

    #[test]
    fn busy_delays_retry_honoring_hint() {
        let mut c = Client::new(10, vec![0], WorkloadSpec::closed_loop());
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let mut fx2 = Effects::new();
        c.on_msg(MS, 0, Msg::Busy { group: 0, seq: 1, retry_after_us: 5_000 }, &mut fx2);
        assert_eq!(c.busy_observed, 1);
        // No immediate resend, and seq 1 stays outstanding: a Busy is a
        // drop, not an ack — `lowest` must not advance past it.
        assert!(sent_seqs(&fx2).is_empty());
        assert_eq!(c.lowest_outstanding(), 1);
        // One retry timer, ≥ the 5 ms hint plus bounded jitter.
        let (delay, timer) = next_resend(&fx2).unwrap();
        assert!(matches!(timer, Timer::ClientResend { seq: 1, .. }));
        assert!(delay >= 5 * MS && delay < 5 * MS + 2 * MS, "delay {delay}");
        // The send-time resend timer went stale (generation bumped):
        // pushback replaces the blind resend instead of racing it.
        let mut fx3 = Effects::new();
        c.on_timer(10 * MS, Timer::ClientResend { seq: 1, generation: 1 }, &mut fx3);
        assert!(sent_seqs(&fx3).is_empty());
        // The Busy-armed timer fires the (single) delayed retry.
        let mut fx4 = Effects::new();
        c.on_timer(MS + delay, timer, &mut fx4);
        assert_eq!(sent_seqs(&fx4), vec![1]);
    }

    #[test]
    fn open_loop_queue_bounded_by_cap() {
        // Regression (satellite fix — unbounded queue): arrivals past
        // `max_in_flight` + `queue_cap` are shed into `abandoned`, so
        // the memory-resident backlog stays ≤ cap past saturation.
        let spec = WorkloadSpec::open_loop(1000.0).max_in_flight(1).queue_cap(2);
        let mut c = Client::new(10, vec![0], spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx); // seq 1 in flight
        for i in 1..=5u64 {
            let mut f = Effects::new();
            c.on_timer(i * MS, Timer::Wakeup { tag: TAG_ARRIVAL }, &mut f);
        }
        assert_eq!(c.offered, 6);
        assert_eq!(c.in_flight(), 1);
        assert_eq!(c.backlog.len(), 2, "backlog capped");
        assert_eq!(c.abandoned, 3, "overflow counted as abandoned");
        // Replies drain the backlog normally — shed arrivals are gone.
        reply(&mut c, 10 * MS, 1);
        assert_eq!(c.backlog.len(), 1);
    }
}
