//! The shard-routing workload client: one client, many consensus groups.
//!
//! A sharded deployment ([`crate::harness::ShardedCluster`]) runs N
//! independent Matchmaker MultiPaxos groups behind one shared matchmaker
//! set. The [`ShardClient`] spreads a [`WorkloadSpec`]-driven key stream
//! across those groups: every request draws a key from the spec's key
//! space, the key hashes to a group ([`shard_of`]), and the request goes
//! to that group's leader. Routing is *static* — a key always lands on
//! the same group — which is what makes per-key operations linearizable
//! across the whole sharded deployment: all commands for a key serialize
//! through one group's log.
//!
//! Sequencing is **per lane**: the client keeps an independent,
//! contiguous seq stream (1, 2, 3, ...) for each group, so each group
//! leader's per-client sequencer ([`crate::roles::sequencer`]) sees
//! exactly the contiguous stream it expects and per-client FIFO holds
//! *within* each shard. (Cross-shard ordering is deliberately not
//! promised — that is the sharding trade-off; per-key ordering is what
//! survives, via static routing.) Replies and resend timers carry the
//! group ([`Msg::ClientReply`], [`Timer::ShardResend`]) because seq
//! numbers alone are ambiguous across lanes.
//!
//! The workload modes mirror the single-group [`crate::roles::Client`]:
//! closed-loop/pipelined keeps a *total* window of requests in flight
//! (spread over the groups the drawn keys land on); open loop offers
//! arrivals at the configured rate with a total in-flight bound and
//! client-side queueing, measuring latency from arrival.
//!
//! NOTE: the engine (arrival/backlog/resend/redirect-throttle logic) is
//! deliberately kept in lockstep with `roles/client.rs` rather than
//! shared — the lane indirection touches every line, and the two roles'
//! offered/completed/abandoned semantics must stay identical for the
//! X4-vs-X6 comparisons to be apples-to-apples. A behavioral fix to one
//! client must be mirrored in the other.

use super::client::backoff_delay;
use crate::msg::{Command, Msg};
use crate::node::{Effects, Node, Timer};
use crate::util::Rng;
use crate::workload::{WorkloadMode, WorkloadSpec};
use crate::{GroupId, NodeId, Time, MS, US};
use std::collections::{BTreeMap, VecDeque};

/// `Timer::Wakeup` tag: delayed start (`WorkloadSpec::start_at`).
pub const TAG_START: u64 = 0;
/// `Timer::Wakeup` tag: open-loop arrival tick.
pub const TAG_ARRIVAL: u64 = 1;

/// Deterministic key → group routing: splitmix64 finalizer over the key,
/// reduced mod the group count. Stateless and stable, so every client —
/// and every test checking routing — agrees on the key's home group.
pub fn shard_of(key: u64, shards: usize) -> GroupId {
    debug_assert!(shards > 0, "shard_of with zero shards");
    let mut z = key.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % shards as u64) as GroupId
}

/// Extract the routing key from a [`ShardClient`] command payload (the
/// first 8 bytes, little-endian). Safety tests use this to verify that
/// every chosen command actually lives in its key's home group.
pub fn key_of_payload(payload: &[u8]) -> Option<u64> {
    payload.get(..8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
}

/// One in-flight request of a lane.
#[derive(Clone, Copy, Debug)]
struct Outstanding {
    /// Arrival time the latency clock runs from.
    issued_at: Time,
    /// Matches the most recently armed resend timer.
    generation: u64,
    /// The routing key (resends must rebuild the same payload).
    key: u64,
    /// Whether this operation is a read (read payload; recorded
    /// separately on completion). Reads ride the replica path when the
    /// lane knows its replicas, else through the log (baseline).
    read: bool,
    /// Resend attempts so far (capped exponential backoff; reset-on-
    /// reply falls out of entry removal). Mirrors
    /// [`crate::roles::Client`].
    attempt: u32,
}

/// Per-group client state: an independent seq stream, in-flight window
/// slice, and leader hint for one consensus group.
#[derive(Debug)]
struct Lane {
    group: GroupId,
    /// The group's proposers, in fallback order.
    proposers: Vec<NodeId>,
    leader_hint: usize,
    /// The group's replicas: linearizable-read targets (empty = route
    /// reads through the log; see [`ShardClient::replicas_per_group`]).
    replicas: Vec<NodeId>,
    /// Rotation offset into `replicas` for read targeting.
    replica_hint: usize,
    /// Next seq to assign in this lane (first command is seq 1).
    next_seq: u64,
    outstanding: BTreeMap<u64, Outstanding>,
    /// Next read seq in this lane (reads have their own seq space so
    /// they never perturb the group leader's FIFO sequencer).
    read_next_seq: u64,
    read_outstanding: BTreeMap<u64, Outstanding>,
    /// Bumped on every (re)send in this lane; stale timers are ignored.
    generation: u64,
    /// Redirect-storm throttle (see [`crate::roles::Client`]).
    last_redirect: Time,
    last_probe: Time,
    /// `NotLeaseholder` redirect throttle for the read window.
    last_read_redirect: Time,
    /// Busy-pushback horizon: this lane's leader asked for backoff
    /// until here. Backlog draining prefers lanes whose horizon has
    /// passed (route queued traffic around hot groups).
    busy_until: Time,
    /// `Msg::Busy` pushbacks this lane has received (load metrics).
    busy_seen: u64,
}

impl Lane {
    fn leader(&self) -> NodeId {
        self.proposers[self.leader_hint % self.proposers.len()]
    }

    /// Oldest in-flight seq of this lane (the `ClientRequest.lowest`
    /// the group's sequencer keys on).
    fn lowest(&self) -> u64 {
        self.outstanding.keys().next().copied().unwrap_or(self.next_seq)
    }
}

/// A workload client that routes keys across the groups of a sharded
/// deployment. See the module docs for the routing and sequencing rules.
pub struct ShardClient {
    /// This node's id (doubles as the `Command::client` identity in
    /// every lane).
    pub id: NodeId,
    /// The workload this client runs (window/rate bounds are *total*
    /// across lanes).
    pub spec: WorkloadSpec,
    /// Completed-request samples `(completion_time, latency_ns)`, all
    /// lanes merged.
    pub samples: Vec<(Time, Time)>,
    /// Requests generated (arrivals or window sends), all lanes.
    pub offered: u64,
    /// Requests completed.
    pub completed: u64,
    /// Requests dropped at the stop deadline, shed on `Busy` pushback
    /// (`shed_on_busy`), or dropped at a full arrival queue
    /// (`queue_cap`).
    pub abandoned: u64,
    /// `Msg::Busy` pushbacks received across all lanes (admission
    /// control; per-lane counts live in [`ShardClient::lane_load`]).
    pub busy_observed: u64,
    /// Policy on `Busy` pushback: `true` sheds (drop + `abandoned`),
    /// `false` (default) retries after the leader's hint. Wired by the
    /// harness from [`crate::config::AdmissionSpec::shed`]. Mirrors
    /// [`crate::roles::Client::shed_on_busy`].
    pub shed_on_busy: bool,
    /// Reads completed (subset of `completed`).
    pub reads_completed: u64,
    /// Completed writes `(issued_at, completed_at)`, all lanes merged.
    pub writes: Vec<(Time, Time)>,
    /// Issue times of every write ever sent (including never-completed
    /// ones — see [`crate::roles::Client::write_issues`]).
    pub write_issues: Vec<Time>,
    /// Completed reads `(issued_at, completed_at, result)`, all lanes.
    pub reads: Vec<(Time, Time, Vec<u8>)>,

    lanes: Vec<Lane>,
    /// Open-loop arrivals waiting for a free in-flight slot: `(arrival
    /// time, key, read?)`. Key and classification are drawn at arrival
    /// so routing and mix are arrival-deterministic, not
    /// drain-order-dependent.
    backlog: VecDeque<(Time, u64, bool)>,
    /// Total requests on the wire across all lanes (reads + writes).
    in_flight: usize,
    /// Per-command payload suffix (resolved from the spec once); the
    /// 8-byte key prefix is prepended per request.
    payload_suffix: Vec<u8>,
    /// Per-read payload suffix (resolved once), same key-prefix scheme.
    read_payload_suffix: Vec<u8>,
    /// Deterministic per-client RNG: key draws + Poisson gaps.
    rng: Rng,
}

impl ShardClient {
    /// A client spreading `spec`'s key stream across `groups`, where
    /// `groups[g]` lists group g's proposers. `groups` must cover every
    /// group id `0..groups.len()` in order.
    pub fn new(id: NodeId, groups: Vec<Vec<NodeId>>, spec: WorkloadSpec) -> ShardClient {
        assert!(!groups.is_empty(), "ShardClient needs at least one group");
        let payload_suffix = spec.payload.bytes_for(id);
        let read_payload_suffix = spec.read_payload.bytes_for(id);
        ShardClient {
            id,
            lanes: groups
                .into_iter()
                .enumerate()
                .map(|(g, proposers)| Lane {
                    group: g as GroupId,
                    proposers,
                    leader_hint: 0,
                    replicas: Vec::new(),
                    replica_hint: 0,
                    next_seq: 1,
                    outstanding: BTreeMap::new(),
                    read_next_seq: 1,
                    read_outstanding: BTreeMap::new(),
                    generation: 0,
                    last_redirect: 0,
                    last_probe: 0,
                    last_read_redirect: 0,
                    busy_until: 0,
                    busy_seen: 0,
                })
                .collect(),
            spec,
            samples: Vec::new(),
            offered: 0,
            completed: 0,
            abandoned: 0,
            busy_observed: 0,
            shed_on_busy: false,
            reads_completed: 0,
            writes: Vec::new(),
            write_issues: Vec::new(),
            reads: Vec::new(),
            backlog: VecDeque::new(),
            in_flight: 0,
            payload_suffix,
            read_payload_suffix,
            rng: Rng::new(0x51ab_c11e_0000_0000 ^ id as u64),
        }
    }

    /// Wire each group's replica set (read targets), in group order.
    /// Without this, read-classified requests ride the log (baseline).
    pub fn replicas_per_group(&mut self, replicas: Vec<Vec<NodeId>>) {
        for (lane, reps) in self.lanes.iter_mut().zip(replicas) {
            lane.replicas = reps;
        }
    }

    /// Total requests currently on the wire.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Per-lane completed-request sanity view: `(group, next_seq)` —
    /// tests use it to confirm keys actually spread across groups.
    pub fn lane_seqs(&self) -> Vec<(GroupId, u64)> {
        self.lanes.iter().map(|l| (l.group, l.next_seq)).collect()
    }

    /// Per-lane load view for the harness's per-group metrics:
    /// `(group, Busy pushbacks seen, Busy horizon)`.
    pub fn lane_load(&self) -> Vec<(GroupId, u64, Time)> {
        self.lanes.iter().map(|l| (l.group, l.busy_seen, l.busy_until)).collect()
    }

    fn payload_for(&self, key: u64, read: bool) -> Vec<u8> {
        let suffix = if read { &self.read_payload_suffix } else { &self.payload_suffix };
        let mut p = Vec::with_capacity(8 + suffix.len());
        p.extend_from_slice(&key.to_le_bytes());
        p.extend_from_slice(suffix);
        p
    }

    fn draw_key(&mut self) -> u64 {
        self.rng.gen_range(self.spec.keys.max(1))
    }

    /// Draw the read/write classification (RNG untouched at
    /// `read_fraction == 0`, keeping all-write runs bit-identical).
    fn classify(&mut self) -> bool {
        self.spec.read_fraction > 0.0 && self.rng.next_f64() < self.spec.read_fraction
    }

    /// Route one new operation: reads go to a replica of the key's home
    /// group when that lane knows its replicas, else through the log.
    fn dispatch(&mut self, key: u64, read: bool, issued_at: Time, now: Time, fx: &mut Effects) {
        let lane_idx = shard_of(key, self.lanes.len()) as usize;
        if read && !self.lanes[lane_idx].replicas.is_empty() {
            self.send_read(key, issued_at, now, fx);
        } else {
            self.send_request(key, read, issued_at, now, fx);
        }
    }

    /// Issue a brand-new request for `key` through its home lane's log.
    fn send_request(&mut self, key: u64, read: bool, issued_at: Time, _now: Time, fx: &mut Effects) {
        let payload = self.payload_for(key, read);
        if !read {
            self.write_issues.push(issued_at);
        }
        let lane = &mut self.lanes[shard_of(key, self.lanes.len()) as usize];
        let seq = lane.next_seq;
        lane.next_seq += 1;
        lane.generation += 1;
        lane.outstanding.insert(
            seq,
            Outstanding { issued_at, generation: lane.generation, key, read, attempt: 0 },
        );
        self.in_flight += 1;
        let cmd = Command { client: self.id, seq, payload };
        let lowest = lane.lowest();
        fx.send(lane.leader(), Msg::ClientRequest { group: lane.group, cmd, lowest });
        fx.timer(
            self.spec.resend_after,
            Timer::ShardResend { group: lane.group, seq, generation: lane.generation },
        );
    }

    /// Issue a brand-new linearizable read for `key` to a replica of
    /// its home group (spread by read seq plus the rotation hint).
    fn send_read(&mut self, key: u64, issued_at: Time, _now: Time, fx: &mut Effects) {
        let payload = self.payload_for(key, true);
        let lane = &mut self.lanes[shard_of(key, self.lanes.len()) as usize];
        let seq = lane.read_next_seq;
        lane.read_next_seq += 1;
        lane.generation += 1;
        lane.read_outstanding.insert(
            seq,
            Outstanding { issued_at, generation: lane.generation, key, read: true, attempt: 0 },
        );
        self.in_flight += 1;
        let n = lane.replicas.len();
        let target = lane.replicas[(seq as usize + lane.replica_hint) % n];
        fx.send(target, Msg::Read { group: lane.group, seq, payload });
        fx.timer(
            self.spec.resend_after,
            Timer::ShardReadResend { group: lane.group, seq, generation: lane.generation },
        );
    }

    /// Re-send one in-flight read of a lane (rotated target), bounded
    /// by the stop deadline.
    fn resend_read_one(&mut self, lane_idx: usize, seq: u64, now: Time, fx: &mut Effects) {
        if now >= self.spec.stop_at {
            if self.lanes[lane_idx].read_outstanding.remove(&seq).is_some() {
                self.abandoned += 1;
                self.in_flight -= 1;
            }
            return;
        }
        let Some(&Outstanding { key, .. }) = self.lanes[lane_idx].read_outstanding.get(&seq)
        else {
            return;
        };
        let id = self.id;
        let resend_after = self.spec.resend_after;
        let payload = self.payload_for(key, true);
        let lane = &mut self.lanes[lane_idx];
        if lane.replicas.is_empty() {
            return;
        }
        lane.generation += 1;
        let generation = lane.generation;
        let o = lane.read_outstanding.get_mut(&seq).unwrap();
        o.generation = generation;
        o.attempt = o.attempt.saturating_add(1);
        let attempt = o.attempt;
        let n = lane.replicas.len();
        let target = lane.replicas[(seq as usize + lane.replica_hint) % n];
        fx.send(target, Msg::Read { group: lane.group, seq, payload });
        // Jitter keys on the lane-qualified seq (seq spaces repeat
        // across lanes) — see `backoff_delay`.
        let delay =
            backoff_delay(resend_after, id, seq ^ ((lane.group as u64) << 40), attempt);
        fx.timer(delay, Timer::ShardReadResend { group: lane.group, seq, generation });
    }

    /// Re-send one in-flight request of a lane, bounded by the stop
    /// deadline (mirrors [`crate::roles::Client`]).
    fn resend_one(&mut self, lane_idx: usize, seq: u64, now: Time, fx: &mut Effects) {
        if now >= self.spec.stop_at {
            if self.lanes[lane_idx].outstanding.remove(&seq).is_some() {
                self.abandoned += 1;
                self.in_flight -= 1;
            }
            return;
        }
        let id = self.id;
        let resend_after = self.spec.resend_after;
        let Some(&Outstanding { key, read, .. }) = self.lanes[lane_idx].outstanding.get(&seq)
        else {
            return;
        };
        let payload = self.payload_for(key, read);
        let lane = &mut self.lanes[lane_idx];
        lane.generation += 1;
        let generation = lane.generation;
        let o = lane.outstanding.get_mut(&seq).unwrap();
        o.generation = generation;
        o.attempt = o.attempt.saturating_add(1);
        let attempt = o.attempt;
        let cmd = Command { client: id, seq, payload };
        let lowest = lane.lowest();
        fx.send(lane.leader(), Msg::ClientRequest { group: lane.group, cmd, lowest });
        let delay =
            backoff_delay(resend_after, id, seq ^ ((lane.group as u64) << 40), attempt);
        fx.timer(delay, Timer::ShardResend { group: lane.group, seq, generation });
    }

    /// Closed-loop refill: keep `window` requests in flight in total,
    /// each routed by a freshly drawn key.
    fn fill_window(&mut self, now: Time, fx: &mut Effects) {
        let WorkloadMode::ClosedLoop { window } = self.spec.mode else {
            return;
        };
        while self.in_flight < window && now < self.spec.stop_at {
            self.offered += 1;
            let key = self.draw_key();
            let read = self.classify();
            self.dispatch(key, read, now, now, fx);
        }
    }

    /// One open-loop arrival at `now`; schedules the next tick.
    fn on_arrival(&mut self, now: Time, fx: &mut Effects) {
        let WorkloadMode::OpenLoop { interval, poisson, max_in_flight, queue_cap } =
            self.spec.mode
        else {
            return;
        };
        if now >= self.spec.stop_at {
            return; // stop the arrival chain
        }
        self.offered += 1;
        let key = self.draw_key();
        let read = self.classify();
        if self.in_flight < max_in_flight {
            self.dispatch(key, read, now, now, fx);
        } else if self.backlog.len() < queue_cap {
            self.backlog.push_back((now, key, read));
        } else {
            // Queue bound (satellite fix): shed the arrival instead of
            // growing the backlog without limit past saturation.
            self.abandoned += 1;
        }
        let gap = if poisson {
            let u = self.rng.next_f64();
            ((-(1.0 - u).ln()) * interval as f64) as Time
        } else {
            interval
        };
        fx.timer(gap.max(1), Timer::Wakeup { tag: TAG_ARRIVAL });
    }

    /// A completion freed an in-flight slot: refill the window or drain
    /// one backlogged arrival (abandoning the backlog past `stop_at`).
    /// Draining prefers arrivals whose home lane is not under `Busy`
    /// pushback — queued traffic routes around hot groups while their
    /// horizon passes (strict FIFO when every candidate lane is hot, and
    /// with admission disabled `busy_until` is always 0, so this is
    /// plain FIFO).
    fn refill(&mut self, now: Time, fx: &mut Effects) {
        match self.spec.mode {
            WorkloadMode::ClosedLoop { .. } => self.fill_window(now, fx),
            WorkloadMode::OpenLoop { .. } => {
                if now >= self.spec.stop_at {
                    self.abandoned += self.backlog.len() as u64;
                    self.backlog.clear();
                } else if !self.backlog.is_empty() {
                    let n = self.lanes.len();
                    // Bounded scan: hot-lane avoidance must not turn a
                    // deep backlog into an O(len) search per completion.
                    let pick = self
                        .backlog
                        .iter()
                        .take(16)
                        .position(|&(_, key, _)| {
                            self.lanes[shard_of(key, n) as usize].busy_until <= now
                        })
                        .unwrap_or(0);
                    let (arrived, key, read) =
                        self.backlog.remove(pick).expect("index within backlog");
                    self.dispatch(key, read, arrived, now, fx);
                }
            }
        }
    }

    fn begin(&mut self, now: Time, fx: &mut Effects) {
        match self.spec.mode {
            WorkloadMode::ClosedLoop { .. } => self.fill_window(now, fx),
            WorkloadMode::OpenLoop { .. } => self.on_arrival(now, fx),
        }
    }

    fn lane_index(&self, group: GroupId) -> Option<usize> {
        // Lanes are built in group order (0..n), but stay defensive
        // against a stray group tag from a confused peer.
        let idx = group as usize;
        (idx < self.lanes.len() && self.lanes[idx].group == group).then_some(idx)
    }
}

impl Node for ShardClient {
    fn on_start(&mut self, now: Time, fx: &mut Effects) {
        if self.spec.start_at > now {
            fx.timer(self.spec.start_at - now, Timer::Wakeup { tag: TAG_START });
        } else {
            self.begin(now, fx);
        }
    }

    fn on_msg(&mut self, now: Time, _from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::ClientReply { group, seq, result } => {
                let Some(idx) = self.lane_index(group) else {
                    return;
                };
                let Some(o) = self.lanes[idx].outstanding.remove(&seq) else {
                    return; // stale/duplicate reply (other replicas)
                };
                self.in_flight -= 1;
                self.samples.push((now, now - o.issued_at));
                self.completed += 1;
                if o.read {
                    self.reads_completed += 1;
                    self.reads.push((o.issued_at, now, result));
                } else {
                    self.writes.push((o.issued_at, now));
                }
                self.refill(now, fx);
            }
            Msg::ReadReply { group, seq, result } => {
                let Some(idx) = self.lane_index(group) else {
                    return;
                };
                let Some(o) = self.lanes[idx].read_outstanding.remove(&seq) else {
                    return; // stale/duplicate reply
                };
                self.in_flight -= 1;
                self.samples.push((now, now - o.issued_at));
                self.completed += 1;
                self.reads_completed += 1;
                self.reads.push((o.issued_at, now, result));
                self.refill(now, fx);
            }
            Msg::Busy { group, seq, retry_after_us } => {
                // Admission pushback from this lane's leader (DESIGN.md
                // §Overload). The request was dropped without sequencer
                // side effects, so shedding or delayed retry are both
                // safe; either way the lane is marked hot so backlog
                // draining steers around it. Mirrors
                // [`crate::roles::Client`].
                let Some(idx) = self.lane_index(group) else {
                    return;
                };
                if !self.lanes[idx].outstanding.contains_key(&seq) {
                    return; // stale Busy for a request that since completed
                }
                self.busy_observed += 1;
                let hint = retry_after_us.max(1) * US;
                let lane = &mut self.lanes[idx];
                lane.busy_until = lane.busy_until.max(now.saturating_add(hint));
                lane.busy_seen += 1;
                if self.shed_on_busy {
                    self.lanes[idx].outstanding.remove(&seq);
                    self.in_flight -= 1;
                    self.abandoned += 1;
                    self.refill(now, fx);
                } else {
                    let id = self.id;
                    let lane = &mut self.lanes[idx];
                    lane.generation += 1;
                    let generation = lane.generation;
                    let o = lane.outstanding.get_mut(&seq).expect("checked above");
                    o.generation = generation;
                    o.attempt = o.attempt.saturating_add(1);
                    let attempt = o.attempt;
                    let delay = backoff_delay(
                        hint,
                        id,
                        seq ^ ((group as u64) << 40),
                        attempt.saturating_sub(1),
                    );
                    fx.timer(delay, Timer::ShardResend { group, seq, generation });
                }
            }
            Msg::NotLeaseholder { group, hint: _ } => {
                let Some(idx) = self.lane_index(group) else {
                    return;
                };
                let lane = &mut self.lanes[idx];
                lane.replica_hint = lane.replica_hint.wrapping_add(1);
                if now.saturating_sub(lane.last_read_redirect) >= MS
                    || lane.last_read_redirect == 0
                {
                    lane.last_read_redirect = now.max(1);
                    let seqs: Vec<u64> = lane.read_outstanding.keys().copied().collect();
                    for seq in seqs {
                        self.resend_read_one(idx, seq, now, fx);
                    }
                }
            }
            Msg::NotLeader { group, hint } => {
                let Some(idx) = self.lane_index(group) else {
                    return;
                };
                let lane = &mut self.lanes[idx];
                if let Some(h) = hint {
                    if let Some(i) = lane.proposers.iter().position(|&p| p == h) {
                        lane.leader_hint = i;
                    }
                } else {
                    lane.leader_hint = (lane.leader_hint + 1) % lane.proposers.len();
                }
                // Same redirect-storm throttle as the single-group
                // client, but per lane: re-send the lane's window at most
                // once per ms, with an RTT-scale single-request probe in
                // between.
                if now.saturating_sub(lane.last_redirect) >= MS || lane.last_redirect == 0 {
                    lane.last_redirect = now.max(1);
                    let seqs: Vec<u64> = lane.outstanding.keys().copied().collect();
                    for seq in seqs {
                        self.resend_one(idx, seq, now, fx);
                    }
                } else if now.saturating_sub(lane.last_probe) >= 100 * US {
                    lane.last_probe = now;
                    if let Some(&oldest) = lane.outstanding.keys().next() {
                        self.resend_one(idx, oldest, now, fx);
                    }
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, now: Time, timer: Timer, fx: &mut Effects) {
        match timer {
            Timer::ShardResend { group, seq, generation } => {
                let Some(idx) = self.lane_index(group) else {
                    return;
                };
                let lane = &mut self.lanes[idx];
                let live = lane
                    .outstanding
                    .get(&seq)
                    .map_or(false, |o| o.generation == generation);
                if live {
                    // The group's leader may have failed: rotate the
                    // lane's hint, but only on the oldest request's
                    // timeout so a burst rotates once.
                    if lane.lowest() == seq {
                        lane.leader_hint = (lane.leader_hint + 1) % lane.proposers.len();
                    }
                    self.resend_one(idx, seq, now, fx);
                }
            }
            Timer::ShardReadResend { group, seq, generation } => {
                let Some(idx) = self.lane_index(group) else {
                    return;
                };
                let lane = &mut self.lanes[idx];
                let live = lane
                    .read_outstanding
                    .get(&seq)
                    .map_or(false, |o| o.generation == generation);
                if live {
                    // Rotate the lane's replica target on the oldest
                    // read's timeout (one rotation per burst).
                    if lane.read_outstanding.keys().next() == Some(&seq) {
                        lane.replica_hint = lane.replica_hint.wrapping_add(1);
                    }
                    self.resend_read_one(idx, seq, now, fx);
                }
            }
            Timer::Wakeup { tag: TAG_START } => self.begin(now, fx),
            Timer::Wakeup { tag: TAG_ARRIVAL } => self.on_arrival(now, fx),
            Timer::Wakeup { tag } => {
                debug_assert!(false, "shard client {}: unknown wakeup tag {tag}", self.id);
            }
            _ => {}
        }
    }

    fn role(&self) -> &'static str {
        "shard-client"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    fn sent(fx: &Effects) -> Vec<(NodeId, GroupId, u64, u64)> {
        fx.msgs
            .iter()
            .filter_map(|(to, m)| match m {
                Msg::ClientRequest { group, cmd, lowest } => {
                    Some((*to, *group, cmd.seq, *lowest))
                }
                _ => None,
            })
            .collect()
    }

    fn two_group_client(spec: WorkloadSpec) -> ShardClient {
        // Group 0 leaders: 0, 1; group 1 leaders: 10, 11.
        ShardClient::new(100, vec![vec![0, 1], vec![10, 11]], spec)
    }

    #[test]
    fn routing_is_deterministic_and_covers_groups() {
        for shards in 1..=8 {
            let mut seen = vec![false; shards];
            for key in 0..64u64 {
                let g = shard_of(key, shards);
                assert_eq!(g, shard_of(key, shards), "routing must be stable");
                assert!((g as usize) < shards);
                seen[g as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "64 keys must cover {shards} shards");
        }
    }

    #[test]
    fn payload_carries_routing_key() {
        let mut c = two_group_client(WorkloadSpec::pipelined(4).payload_bytes(3));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        for (_, group, _, _) in sent(&fx) {
            assert!(group <= 1);
        }
        for (_, m) in &fx.msgs {
            if let Msg::ClientRequest { group, cmd, .. } = m {
                let key = key_of_payload(&cmd.payload).expect("key prefix");
                assert_eq!(shard_of(key, 2), *group, "payload key must route to its group");
                assert_eq!(cmd.payload.len(), 8 + 3);
            }
        }
    }

    #[test]
    fn window_spreads_lanes_with_contiguous_seqs() {
        let mut c = two_group_client(WorkloadSpec::pipelined(8));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(c.in_flight(), 8);
        let sends = sent(&fx);
        assert_eq!(sends.len(), 8);
        // Each lane's seqs are contiguous from 1 regardless of how the
        // keys split across groups.
        for lane in 0..2u32 {
            let seqs: Vec<u64> =
                sends.iter().filter(|s| s.1 == lane).map(|s| s.2).collect();
            let expect: Vec<u64> = (1..=seqs.len() as u64).collect();
            assert_eq!(seqs, expect, "lane {lane} seqs not contiguous");
        }
        // The lane split matches the client's deterministic key stream:
        // replicate the draws with the same seed and routing.
        let mut rng = Rng::new(0x51ab_c11e_0000_0000 ^ 100u64);
        let expected: Vec<GroupId> =
            (0..8).map(|_| shard_of(rng.gen_range(1024), 2)).collect();
        let actual: Vec<GroupId> = sends.iter().map(|s| s.1).collect();
        assert_eq!(actual, expected, "sends must follow the drawn key stream");
        // And the per-lane seq cursors agree with the spread: lane g's
        // next_seq is one past the number of keys that landed on it.
        for (g, next_seq) in c.lane_seqs() {
            let landed = expected.iter().filter(|&&e| e == g).count() as u64;
            assert_eq!(next_seq, landed + 1, "lane {g} cursor out of step");
        }
    }

    #[test]
    fn reply_refills_window_on_any_lane() {
        let mut c = two_group_client(WorkloadSpec::pipelined(4));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let first = sent(&fx)[0];
        let mut fx2 = Effects::new();
        c.on_msg(
            MS,
            0,
            Msg::ClientReply { group: first.1, seq: first.2, result: vec![] },
            &mut fx2,
        );
        assert_eq!(c.completed, 1);
        assert_eq!(c.samples.len(), 1);
        assert_eq!(c.in_flight(), 4, "window refilled");
        assert_eq!(sent(&fx2).len(), 1);
    }

    #[test]
    fn reply_with_unknown_group_is_ignored() {
        let mut c = two_group_client(WorkloadSpec::pipelined(2));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let first = sent(&fx)[0];
        let before = c.in_flight();
        // A reply tagged with a group this client has no lane for must
        // not complete anything (seq spaces are per lane).
        let mut fx2 = Effects::new();
        c.on_msg(MS, 0, Msg::ClientReply { group: 99, seq: first.2, result: vec![] }, &mut fx2);
        assert_eq!(c.in_flight(), before);
        assert_eq!(c.completed, 0);
        // The correctly tagged reply still lands.
        let mut fx3 = Effects::new();
        c.on_msg(MS, 0, Msg::ClientReply { group: first.1, seq: first.2, result: vec![] }, &mut fx3);
        assert_eq!(c.completed, 1);
    }

    #[test]
    fn open_loop_backlog_preserves_arrival_key_and_time() {
        let spec = WorkloadSpec::open_loop(1000.0).max_in_flight(1);
        let mut c = two_group_client(spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(c.in_flight(), 1);
        let mut fx2 = Effects::new();
        c.on_timer(MS, Timer::Wakeup { tag: TAG_ARRIVAL }, &mut fx2);
        assert_eq!(c.backlog.len(), 1, "second arrival queues");
        assert_eq!(c.offered, 2);
        let (arrived, queued_key, _) = c.backlog[0];
        assert_eq!(arrived, MS);
        // Complete the in-flight request: the backlogged key drains to
        // its own home lane with latency from its arrival time.
        let first = sent(&fx)[0];
        let mut fx3 = Effects::new();
        c.on_msg(
            3 * MS,
            0,
            Msg::ClientReply { group: first.1, seq: first.2, result: vec![] },
            &mut fx3,
        );
        let drained = sent(&fx3);
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].1, shard_of(queued_key, 2));
        let lane = &c.lanes[drained[0].1 as usize];
        let o = lane.outstanding.get(&drained[0].2).unwrap();
        assert_eq!(o.issued_at, MS, "latency runs from arrival");
    }

    #[test]
    fn resend_timer_routes_to_its_lane_and_rotates_hint() {
        let mut c = two_group_client(WorkloadSpec::pipelined(2));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let (_, group, seq, _) = sent(&fx)[0];
        let lane_gen = c.lanes[group as usize].generation;
        let hint_before = c.lanes[group as usize].leader_hint;
        let mut fx2 = Effects::new();
        // The timer generation for the most recent send of the oldest
        // request: find it from the outstanding entry.
        let generation = c.lanes[group as usize].outstanding[&seq].generation;
        assert!(generation <= lane_gen);
        c.on_timer(100 * MS, Timer::ShardResend { group, seq, generation }, &mut fx2);
        let resends = sent(&fx2);
        if seq == c.lanes[group as usize].lowest() {
            assert_ne!(c.lanes[group as usize].leader_hint, hint_before, "hint rotated");
        }
        assert_eq!(resends.len(), 1);
        assert_eq!(resends[0].1, group);
        // Stale generation: no-op.
        let mut fx3 = Effects::new();
        c.on_timer(200 * MS, Timer::ShardResend { group, seq, generation }, &mut fx3);
        assert!(sent(&fx3).is_empty());
    }

    #[test]
    fn not_leader_redirects_only_that_lane() {
        let mut c = two_group_client(WorkloadSpec::pipelined(8));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let sends = sent(&fx);
        let lane0_count = sends.iter().filter(|s| s.1 == 0).count();
        assert!(lane0_count >= 1, "seeded draw sends to lane 0");
        let mut fx2 = Effects::new();
        c.on_msg(MS, 0, Msg::NotLeader { group: 0, hint: Some(1) }, &mut fx2);
        assert_eq!(c.lanes[0].leader_hint, 1);
        assert_eq!(c.lanes[1].leader_hint, 0, "other lane untouched");
        let resends = sent(&fx2);
        assert_eq!(resends.len(), lane0_count, "only lane 0's window re-sent");
        assert!(resends.iter().all(|s| s.0 == 1 && s.1 == 0));
    }

    #[test]
    fn reads_route_to_home_group_replicas() {
        let spec = WorkloadSpec::pipelined(8).read_fraction(1.0).read_payload(vec![7]);
        let mut c = two_group_client(spec);
        // Group 0 replicas 30,31; group 1 replicas 40,41.
        c.replicas_per_group(vec![vec![30, 31], vec![40, 41]]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(c.in_flight(), 8);
        let reads: Vec<(NodeId, GroupId, u64)> = fx
            .msgs
            .iter()
            .filter_map(|(to, m)| match m {
                Msg::Read { group, seq, payload } => {
                    // The key prefix routes to the replica's group, and
                    // the read suffix follows it.
                    let key = key_of_payload(payload).expect("key prefix");
                    assert_eq!(shard_of(key, 2), *group);
                    assert_eq!(payload[8..], [7]);
                    Some((*to, *group, *seq))
                }
                _ => None,
            })
            .collect();
        assert_eq!(reads.len(), 8, "all-read mix goes to replicas");
        for (to, group, _) in &reads {
            let expect: &[NodeId] = if *group == 0 { &[30, 31] } else { &[40, 41] };
            assert!(expect.contains(to), "read sent to {to} outside group {group}");
        }
        // Per-lane read seqs are contiguous from 1.
        for lane in 0..2u32 {
            let mut seqs: Vec<u64> =
                reads.iter().filter(|r| r.1 == lane).map(|r| r.2).collect();
            seqs.sort_unstable();
            let expect: Vec<u64> = (1..=seqs.len() as u64).collect();
            assert_eq!(seqs, expect);
        }
        // A ReadReply completes against its lane and refills.
        let (to0, g0, s0) = reads[0];
        let mut fx2 = Effects::new();
        c.on_msg(MS, to0, Msg::ReadReply { group: g0, seq: s0, result: vec![1] }, &mut fx2);
        assert_eq!(c.reads_completed, 1);
        assert_eq!(c.reads.len(), 1);
        assert_eq!(c.in_flight(), 8, "window refilled");
    }

    #[test]
    fn reads_without_replicas_ride_the_log_per_lane() {
        // Baseline: no replica wiring, so read-classified requests go
        // through each lane's leader with the read payload.
        let spec = WorkloadSpec::pipelined(4).read_fraction(1.0).read_payload(vec![7]);
        let mut c = two_group_client(spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert!(fx.msgs.iter().all(|(_, m)| !matches!(m, Msg::Read { .. })));
        let sends = sent(&fx);
        assert_eq!(sends.len(), 4);
        for (_, m) in &fx.msgs {
            if let Msg::ClientRequest { cmd, .. } = m {
                assert_eq!(cmd.payload[8..], [7]);
            }
        }
    }

    #[test]
    fn shard_read_resend_rotates_and_abandons_at_stop() {
        let spec = WorkloadSpec::pipelined(1)
            .read_fraction(1.0)
            .stop_at(crate::SEC);
        let mut c = two_group_client(spec);
        c.replicas_per_group(vec![vec![30, 31], vec![40, 41]]);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let (first_to, group, seq) = fx
            .msgs
            .iter()
            .find_map(|(to, m)| match m {
                Msg::Read { group, seq, .. } => Some((*to, *group, *seq)),
                _ => None,
            })
            .expect("one read in flight");
        let generation = c.lanes[group as usize].read_outstanding[&seq].generation;
        // Timeout: rotated resend within the same group's replicas.
        let mut fx2 = Effects::new();
        c.on_timer(100 * MS, Timer::ShardReadResend { group, seq, generation }, &mut fx2);
        let second = fx2
            .msgs
            .iter()
            .find_map(|(to, m)| match m {
                Msg::Read { .. } => Some(*to),
                _ => None,
            })
            .expect("resend");
        assert_ne!(second, first_to, "rotated to the lane's other replica");
        // Past stop_at: abandoned.
        let generation = c.lanes[group as usize].read_outstanding[&seq].generation;
        let mut fx3 = Effects::new();
        c.on_timer(2 * crate::SEC, Timer::ShardReadResend { group, seq, generation }, &mut fx3);
        assert_eq!(c.abandoned, 1);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn stop_at_abandons_on_resend_deadline() {
        let spec = WorkloadSpec::pipelined(2).stop_at(10 * MS);
        let mut c = two_group_client(spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        assert_eq!(c.in_flight(), 2);
        let (_, group, seq, _) = sent(&fx)[0];
        let generation = c.lanes[group as usize].outstanding[&seq].generation;
        let mut fx2 = Effects::new();
        c.on_timer(100 * MS, Timer::ShardResend { group, seq, generation }, &mut fx2);
        assert!(sent(&fx2).is_empty(), "no resend past the stop deadline");
        assert_eq!(c.abandoned, 1);
        assert_eq!(c.in_flight(), 1);
    }

    // ---- Overload control (DESIGN.md §Overload) ----

    fn next_resend(fx: &Effects) -> Option<(Time, Timer)> {
        fx.timers
            .iter()
            .find(|(_, t)| matches!(t, Timer::ShardResend { .. }))
            .map(|&(d, t)| (d, t))
    }

    #[test]
    fn shard_resend_backoff_bounds_retry_traffic() {
        // Mirror of the single-group client's retry-storm regression:
        // a never-answering group leader sees a handful of resends in
        // 10 virtual seconds, not one per 100 ms.
        let spec = WorkloadSpec::pipelined(1).stop_at(100 * crate::SEC);
        let mut c = two_group_client(spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let (mut delay, mut timer) = next_resend(&fx).unwrap();
        let mut now = 0;
        let mut resends = 0;
        while now + delay <= 10 * crate::SEC {
            now += delay;
            let mut f = Effects::new();
            c.on_timer(now, timer, &mut f);
            resends += sent(&f).len();
            match next_resend(&f) {
                Some((d, t)) => (delay, timer) = (d, t),
                None => break,
            }
        }
        assert!((1..=12).contains(&resends), "retry storm: {resends} resends in 10 s");
        let base = c.spec.resend_after;
        assert!(delay >= 32 * base && delay < 32 * base + base / 4, "uncapped delay {delay}");
        assert_eq!(c.in_flight(), 1, "backoff delays, it never drops");
    }

    #[test]
    fn busy_marks_lane_hot_and_delays_retry() {
        let mut c = two_group_client(WorkloadSpec::pipelined(4));
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let (_, group, seq, _) = sent(&fx)[0];
        let mut fx2 = Effects::new();
        c.on_msg(MS, 0, Msg::Busy { group, seq, retry_after_us: 5_000 }, &mut fx2);
        assert_eq!(c.busy_observed, 1);
        assert!(sent(&fx2).is_empty(), "no immediate resend on pushback");
        // The lane is marked hot until now + hint, and only that lane.
        let load = c.lane_load();
        assert_eq!(load[group as usize].1, 1);
        assert_eq!(load[group as usize].2, MS + 5 * MS);
        assert_eq!(load[1 - group as usize].2, 0, "other lane untouched");
        // Seq stays outstanding: a Busy is a drop, not an ack.
        assert!(c.lanes[group as usize].outstanding.contains_key(&seq));
        // The armed retry waits at least the hint (plus bounded jitter)
        // and fires a single delayed resend.
        let (delay, t) = next_resend(&fx2).unwrap();
        assert!(delay >= 5 * MS && delay < 7 * MS, "delay {delay}");
        let mut fx3 = Effects::new();
        c.on_timer(MS + delay, t, &mut fx3);
        assert_eq!(sent(&fx3).len(), 1, "delayed retry fires");
        assert_eq!(sent(&fx3)[0].1, group);
    }

    #[test]
    fn busy_shed_drops_and_counts() {
        let mut c = two_group_client(WorkloadSpec::pipelined(2));
        c.shed_on_busy = true;
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let (_, group, seq, _) = sent(&fx)[0];
        let inflight_before = c.in_flight();
        let mut fx2 = Effects::new();
        c.on_msg(MS, 0, Msg::Busy { group, seq, retry_after_us: 1_000 }, &mut fx2);
        assert_eq!((c.busy_observed, c.abandoned), (1, 1));
        assert!(!c.lanes[group as usize].outstanding.contains_key(&seq));
        // The freed slot refills with a fresh request (new key draw).
        assert_eq!(c.in_flight(), inflight_before);
        assert_eq!(sent(&fx2).len(), 1);
        // A stale Busy for the shed seq is a no-op.
        let mut fx3 = Effects::new();
        c.on_msg(2 * MS, 0, Msg::Busy { group, seq, retry_after_us: 1_000 }, &mut fx3);
        assert_eq!(c.busy_observed, 1);
    }

    #[test]
    fn backlog_drains_around_hot_lane() {
        let spec = WorkloadSpec::open_loop(1000.0).max_in_flight(1);
        let mut c = two_group_client(spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        let (_, g_first, s_first, _) = sent(&fx)[0];
        let key_for = |g: GroupId| (0u64..).find(|&k| shard_of(k, 2) == g).unwrap();
        // Queue one arrival per lane, lane 0's at the FIFO head, then
        // mark lane 0 hot (as a Busy from its leader would).
        c.backlog.push_back((MS, key_for(0), false));
        c.backlog.push_back((2 * MS, key_for(1), false));
        c.lanes[0].busy_until = 100 * MS;
        // A completion drains the backlog: the cool lane's arrival
        // jumps the queue, the hot lane's stays parked.
        let mut fx2 = Effects::new();
        c.on_msg(
            3 * MS,
            0,
            Msg::ClientReply { group: g_first, seq: s_first, result: vec![] },
            &mut fx2,
        );
        let drained = sent(&fx2);
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].1, 1, "cool lane drained first");
        assert_eq!(c.backlog.len(), 1);
        assert_eq!(c.backlog[0].1, key_for(0), "hot lane's arrival still queued");
        // Once the horizon passes, FIFO resumes on the hot lane.
        let (_, g2, s2, _) = drained[0];
        let mut fx3 = Effects::new();
        c.on_msg(200 * MS, 0, Msg::ClientReply { group: g2, seq: s2, result: vec![] }, &mut fx3);
        assert_eq!(sent(&fx3)[0].1, 0);
    }

    #[test]
    fn open_loop_queue_bounded_by_cap() {
        // Mirror of the single-group client's queue-bound regression.
        let spec = WorkloadSpec::open_loop(1000.0).max_in_flight(1).queue_cap(2);
        let mut c = two_group_client(spec);
        let mut fx = Effects::new();
        c.on_start(0, &mut fx);
        for i in 1..=5u64 {
            let mut f = Effects::new();
            c.on_timer(i * MS, Timer::Wakeup { tag: TAG_ARRIVAL }, &mut f);
        }
        assert_eq!(c.offered, 6);
        assert_eq!(c.backlog.len(), 2, "backlog capped");
        assert_eq!(c.abandoned, 3, "overflow counted as abandoned");
    }
}
