//! The matchmaker — the paper's central contribution (§3 Algorithm 1,
//! §5 Algorithm 4, §6).
//!
//! A matchmaker maintains a log `L` of configurations indexed by round.
//! `MatchA⟨i, C_i⟩` inserts `C_i` at entry `i` and returns every prior
//! configuration, *unless* the log already holds a configuration at a round
//! `≥ i` (or `i` is below the GC watermark), in which case the request is
//! refused — this refusal is exactly what makes the safety proof work: once
//! a matchmaker answers round `i`, it has promised never to answer any
//! round `≤ i` again.
//!
//! Matchmakers also:
//! * serve **many consensus groups at once** (§6: "a single matchmaker
//!   set can serve many protocol instances"): the log is keyed by
//!   `(group, round)` and every matchmaking/GC message names its group.
//!   Groups are fully independent — answering group g's round i promises
//!   nothing about group h, and GC watermarks are per group, so a quiet
//!   group never pins a busy group's entries (and vice versa),
//! * garbage-collect retired configurations (`GarbageA/B`, §5),
//! * support stop-and-copy reconfiguration of the matchmaker set itself
//!   (`StopA/B`, `Bootstrap`, §6), and
//! * double as Paxos acceptors for the meta-Paxos instance that chooses
//!   the next matchmaker set (§6) — processed even while stopped.

use crate::config::Configuration;
use crate::msg::{MmLog, Msg};
use crate::node::{Announce, Effects, Node, Timer};
use crate::round::Round;
use crate::storage::{Storage, WalRecord};
use crate::{GroupId, NodeId, Time};
use std::collections::BTreeMap;

/// A matchmaker node.
#[derive(Debug)]
pub struct Matchmaker {
    /// This node's id.
    pub id: NodeId,
    /// The configuration logs, one `L` per consensus group.
    pub log: MmLog,
    /// Per-group GC watermark `w`: the group's rounds `< w` are retired.
    /// A group absent from the map has GC'd nothing.
    pub gc_watermarks: BTreeMap<GroupId, Round>,
    /// Stopped by `StopA` (§6): refuses everything except `StopA` and the
    /// meta-Paxos messages.
    pub stopped: bool,
    /// New matchmakers are bootstrapped inactive and only start serving
    /// once the meta-Paxos chooses them (`MatchmakersActivated`).
    pub active: bool,
    /// Matchmaker-set generation (§6): generation g's members are the
    /// meta-Paxos acceptors for the instance that chooses generation g+1.
    pub generation: u64,

    // --- Meta-Paxos acceptor state, one single-decree instance per
    // generation: instance g (served by generation-g members) chooses the
    // generation-(g+1) set. Keyed by generation so votes can never leak
    // across instances, even when sets overlap. ---
    meta: BTreeMap<u64, MetaAcceptor>,

    /// Durable log, when attached (`repro run --data-dir`). The `(group,
    /// round)` log, GC watermarks, §6 lifecycle, and meta-Paxos state are
    /// persisted before the corresponding answer leaves the node — the
    /// refusal discipline ("never answer a round ≤ i again") must survive
    /// `kill -9`, or a restarted matchmaker could contradict an answer it
    /// already gave (DESIGN.md §Durability).
    storage: Option<Box<dyn Storage>>,
}

/// Per-instance meta-Paxos acceptor state.
#[derive(Debug, Default, Clone)]
struct MetaAcceptor {
    round: Option<Round>,
    vr: Option<Round>,
    vv: Option<Vec<NodeId>>,
}

impl Matchmaker {
    /// A member of the initial matchmaker set (active immediately).
    pub fn new(id: NodeId) -> Matchmaker {
        Matchmaker {
            id,
            log: BTreeMap::new(),
            gc_watermarks: BTreeMap::new(),
            stopped: false,
            active: true,
            generation: 0,
            meta: BTreeMap::new(),
            storage: None,
        }
    }

    /// A standby matchmaker: inactive until bootstrapped + activated (§6).
    pub fn new_standby(id: NodeId) -> Matchmaker {
        Matchmaker { active: false, ..Matchmaker::new(id) }
    }

    fn below_watermark(&self, group: GroupId, r: Round) -> bool {
        matches!(self.gc_watermarks.get(&group), Some(w) if r < *w)
    }

    /// The number of retained log entries for one group (tests/metrics).
    pub fn group_log_len(&self, group: GroupId) -> usize {
        self.log.get(&group).map_or(0, |l| l.len())
    }

    /// Total retained log entries across all groups — the quantity the
    /// shared-matchmaker memory bound is about.
    pub fn total_log_len(&self) -> usize {
        self.log.values().map(|l| l.len()).sum()
    }

    /// Attach a durable log. Call before the node starts; follow with
    /// [`Matchmaker::recover`] when rejoining after a crash.
    pub fn attach_storage(&mut self, storage: Box<dyn Storage>) {
        self.storage = Some(storage);
    }

    /// Detach and return the durable log (crash simulation).
    pub fn take_storage(&mut self) -> Option<Box<dyn Storage>> {
        self.storage.take()
    }

    /// Append `rec` to the attached log, if any (fatal on failure: a
    /// matchmaker that cannot persist must not answer).
    fn persist(&mut self, rec: WalRecord) {
        if let Some(s) = self.storage.as_mut() {
            s.append(&rec).expect("matchmaker wal append failed");
        }
    }

    /// Persist the §6 lifecycle (generation, stopped, active).
    fn persist_lifecycle(&mut self) {
        if self.storage.is_some() {
            self.persist(WalRecord::MmLifecycle {
                generation: self.generation,
                stopped: self.stopped,
                active: self.active,
            });
        }
    }

    /// Rewrite the durable log to the live set: lifecycle, per-group
    /// watermarks, surviving log entries, and meta-Paxos state. Called
    /// after GC (the retired configurations' records are reclaimed) and
    /// after Bootstrap (the merged state replaces everything).
    fn compact_storage(&mut self) {
        if self.storage.is_none() {
            return;
        }
        let mut live = vec![WalRecord::MmLifecycle {
            generation: self.generation,
            stopped: self.stopped,
            active: self.active,
        }];
        for (&g, &w) in &self.gc_watermarks {
            live.push(WalRecord::MmGcWatermark { group: g, round: w });
        }
        for (&g, glog) in &self.log {
            for (&r, c) in glog {
                live.push(WalRecord::MmEntry { group: g, round: r, config: c.clone() });
            }
        }
        for (&generation, inst) in &self.meta {
            if let Some(round) = inst.round {
                live.push(WalRecord::MetaPromise { generation, round });
            }
            if let (Some(vr), Some(set)) = (inst.vr, inst.vv.clone()) {
                live.push(WalRecord::MetaVote { generation, vr, set });
            }
        }
        let s = self.storage.as_mut().unwrap();
        s.compact(&live).expect("matchmaker wal compact failed");
    }

    /// Rebuild the matchmaker's state by replaying the attached log —
    /// the `kill -9` recovery path. Idempotent over duplicated records
    /// (watermarks ratchet, log/meta inserts are last-write-wins).
    pub fn recover(&mut self) {
        let Some(s) = self.storage.as_mut() else {
            return;
        };
        let recs = s.replay().expect("matchmaker wal replay failed");
        for rec in recs {
            match rec {
                WalRecord::MmEntry { group, round, config } => {
                    self.log.entry(group).or_default().insert(round, config);
                }
                WalRecord::MmGcWatermark { group, round } => {
                    let w = self.gc_watermarks.entry(group).or_insert(round);
                    if round > *w {
                        *w = round;
                    }
                }
                WalRecord::MmLifecycle { generation, stopped, active } => {
                    self.generation = generation;
                    self.stopped = stopped;
                    self.active = active;
                }
                WalRecord::MetaPromise { generation, round } => {
                    let inst = self.meta.entry(generation).or_default();
                    if inst.round.map_or(true, |cur| round > cur) {
                        inst.round = Some(round);
                    }
                }
                WalRecord::MetaVote { generation, vr, set } => {
                    let inst = self.meta.entry(generation).or_default();
                    if inst.vr.map_or(true, |cur| vr >= cur) {
                        inst.vr = Some(vr);
                        inst.vv = Some(set);
                    }
                }
                _ => {}
            }
        }
        // Re-apply each group's watermark to the restored log (records
        // can interleave entries and watermarks in either order).
        for (g, w) in &self.gc_watermarks {
            if let Some(glog) = self.log.get_mut(g) {
                *glog = glog.split_off(w);
            }
        }
    }
}

impl Node for Matchmaker {
    fn on_msg(&mut self, _now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        // Meta-Paxos duty survives stop (§6): the old matchmakers are the
        // acceptors that choose the next matchmaker set.
        match &msg {
            Msg::MetaPhase1A { round, generation } => {
                let inst = self.meta.entry(*generation).or_default();
                if matches!(inst.round, Some(r) if r > *round) {
                    return;
                }
                inst.round = Some(*round);
                let (vr, vv) = (inst.vr, inst.vv.clone());
                if self.storage.is_some() {
                    self.persist(WalRecord::MetaPromise {
                        generation: *generation,
                        round: *round,
                    });
                }
                fx.send(from, Msg::MetaPhase1B { round: *round, vr, vv });
                return;
            }
            Msg::MetaPhase2A { round, generation, matchmakers } => {
                let inst = self.meta.entry(*generation).or_default();
                if matches!(inst.round, Some(r) if r > *round) {
                    return;
                }
                inst.round = Some(*round);
                inst.vr = Some(*round);
                inst.vv = Some(matchmakers.clone());
                if self.storage.is_some() {
                    self.persist(WalRecord::MetaPromise {
                        generation: *generation,
                        round: *round,
                    });
                    self.persist(WalRecord::MetaVote {
                        generation: *generation,
                        vr: *round,
                        set: matchmakers.clone(),
                    });
                }
                fx.send(from, Msg::MetaPhase2B { round: *round });
                return;
            }
            // A stopped matchmaker may be re-used as a member of the *new*
            // set (§6 allows overlapping sets): Bootstrap resurrects it
            // with the merged state, inactive until activation. Meta-Paxos
            // state is untouched — instances are keyed by generation.
            Msg::Bootstrap { log, gc_watermarks, generation } => {
                if *generation <= self.generation {
                    // Stale bootstrap from an abandoned reconfiguration of
                    // an earlier generation: refuse (no ack).
                    return;
                }
                self.log = log.clone();
                self.gc_watermarks = gc_watermarks.clone();
                self.generation = *generation;
                self.stopped = false;
                self.active = false;
                // The merged state replaces everything durably too —
                // a full rewrite, before the ack, so a crashed-and-
                // restarted new matchmaker still holds the merge.
                self.compact_storage();
                fx.send(from, Msg::BootstrapAck);
                return;
            }
            _ => {}
        }

        if self.stopped {
            // A stopped matchmaker answers StopA idempotently and nothing
            // else (§6).
            if matches!(msg, Msg::StopA) {
                fx.send(
                    from,
                    Msg::StopB {
                        log: self.log.clone(),
                        gc_watermarks: self.gc_watermarks.clone(),
                    },
                );
            }
            return;
        }

        match msg {
            // Algorithm 1 + Algorithm 4, per group: the refusal discipline
            // ("once round i is answered, never answer a round ≤ i again")
            // holds within each group's log independently.
            Msg::MatchA { group, round, config } => {
                if !self.active {
                    return;
                }
                if self.below_watermark(group, round) {
                    fx.send(
                        from,
                        Msg::MatchNack {
                            group,
                            round,
                            blocking: self.gc_watermarks[&group],
                        },
                    );
                    return;
                }
                let glog = self.log.entry(group).or_default();
                // ∃ C_j at round j ≥ i (other than an identical re-send)?
                if let Some((&max_r, existing)) = glog.iter().next_back() {
                    if max_r > round || (max_r == round && *existing != config) {
                        fx.send(from, Msg::MatchNack { group, round, blocking: max_r });
                        return;
                    }
                }
                // H_i = all of the group's configurations at rounds < i.
                let prior: BTreeMap<Round, Configuration> =
                    glog.range(..round).map(|(r, c)| (*r, c.clone())).collect();
                // Durable before the MatchB leaves: the answer is the
                // promise, and the promise must survive kill -9.
                if self.storage.is_some() {
                    self.persist(WalRecord::MmEntry { group, round, config: config.clone() });
                }
                self.log.entry(group).or_default().insert(round, config);
                fx.announce(Announce::MatchAnswered { group, round });
                fx.send(
                    from,
                    Msg::MatchB {
                        group,
                        round,
                        gc_watermark: self.gc_watermarks.get(&group).copied(),
                        prior,
                    },
                );
            }

            // Garbage collection (Algorithm 4): delete the group's L[j]
            // for all j < i, raise the group's watermark. Other groups'
            // entries are untouched — per-group GC is what keeps a busy
            // group from pinning (or losing) a quiet group's state.
            Msg::GarbageA { group, round } => {
                if let Some(glog) = self.log.get_mut(&group) {
                    *glog = glog.split_off(&round);
                }
                let w = {
                    let w = self.gc_watermarks.entry(group).or_insert(round);
                    if round > *w {
                        *w = round;
                    }
                    *w
                };
                if self.storage.is_some() {
                    self.persist(WalRecord::MmGcWatermark { group, round: w });
                    // GC is the truncation point: rewrite the log to the
                    // live set so retired configurations are reclaimed
                    // on disk as well as in memory (§5's watermarks
                    // drive the WAL's truncation too).
                    self.compact_storage();
                }
                fx.announce(Announce::MmGc { group, round: w });
                fx.send(from, Msg::GarbageB { group, round });
            }

            // Matchmaker reconfiguration (§6).
            Msg::StopA => {
                self.stopped = true;
                // A stop that does not survive a crash would let the
                // restarted matchmaker keep answering for a set that the
                // reconfigurer already replaced.
                self.persist_lifecycle();
                fx.send(
                    from,
                    Msg::StopB {
                        log: self.log.clone(),
                        gc_watermarks: self.gc_watermarks.clone(),
                    },
                );
            }
            Msg::MatchmakersActivated { generation, .. } => {
                // Activate only our own generation: a stale activation
                // from an earlier migration must not resurrect a node
                // that has since been re-bootstrapped for a newer set.
                if generation == self.generation {
                    self.active = true;
                    self.persist_lifecycle();
                }
            }

            _ => {}
        }
    }

    fn on_timer(&mut self, _now: Time, _timer: Timer, _fx: &mut Effects) {}

    fn role(&self) -> &'static str {
        "matchmaker"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn state_repr(&self) -> Option<String> {
        // Everything a matchmaker holds is protocol state (no clocks, no
        // metrics): the per-group logs, GC watermarks, lifecycle flags,
        // and the per-generation meta-Paxos acceptor state.
        Some(format!(
            "mm log={:?} wm={:?} stopped={} active={} gen={} meta={:?}",
            self.log, self.gc_watermarks, self.stopped, self.active, self.generation, self.meta
        ))
    }
}

/// Merge the multi-group logs returned by `f+1` stopped matchmakers into
/// the initial state for the next matchmaker set (§6, Figure 7), applied
/// per group: union of the group's logs, with every entry below the
/// group's maximum watermark removed.
pub fn merge_stopped(
    states: &[(MmLog, BTreeMap<GroupId, Round>)],
) -> (MmLog, BTreeMap<GroupId, Round>) {
    let mut merged: MmLog = BTreeMap::new();
    let mut wms: BTreeMap<GroupId, Round> = BTreeMap::new();
    for (log, group_wms) in states {
        for (g, glog) in log {
            let m = merged.entry(*g).or_default();
            for (r, c) in glog {
                m.insert(*r, c.clone());
            }
        }
        for (g, w) in group_wms {
            let cur = wms.entry(*g).or_insert(*w);
            if *w > *cur {
                *cur = *w;
            }
        }
    }
    for (g, w) in &wms {
        if let Some(m) = merged.get_mut(g) {
            *m = m.split_off(w);
        }
    }
    (merged, wms)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(n: u64) -> Round {
        Round { epoch: n, proposer: 0, seq: 0 }
    }

    fn cfg(id: u64) -> Configuration {
        Configuration::majority(id, vec![10 + id as NodeId, 11 + id as NodeId, 12 + id as NodeId])
    }

    fn run(m: &mut Matchmaker, msg: Msg) -> Vec<Msg> {
        let mut fx = Effects::new();
        m.on_msg(0, 99, msg, &mut fx);
        fx.msgs.into_iter().map(|(_, m)| m).collect()
    }

    fn match_a(round: Round, config: Configuration) -> Msg {
        Msg::MatchA { group: 0, round, config }
    }

    #[test]
    fn figure3_execution() {
        // Reproduces the matchmaker execution of Figure 3 (group 0).
        let mut m = Matchmaker::new(0);
        let out = run(&mut m, match_a(r(0), cfg(0)));
        assert_eq!(
            out[0],
            Msg::MatchB { group: 0, round: r(0), gc_watermark: None, prior: BTreeMap::new() }
        );
        let out = run(&mut m, match_a(r(2), cfg(2)));
        match &out[0] {
            Msg::MatchB { prior, .. } => {
                assert_eq!(prior.len(), 1);
                assert_eq!(prior[&r(0)], cfg(0));
            }
            other => panic!("{other:?}"),
        }
        let out = run(&mut m, match_a(r(3), cfg(3)));
        match &out[0] {
            Msg::MatchB { prior, .. } => {
                assert_eq!(prior.len(), 2);
                assert!(prior.contains_key(&r(0)) && prior.contains_key(&r(2)));
            }
            other => panic!("{other:?}"),
        }
        // MatchA(1, C1) now refused: the group's log holds rounds ≥ 1.
        let out = run(&mut m, match_a(r(1), cfg(1)));
        assert_eq!(out[0], Msg::MatchNack { group: 0, round: r(1), blocking: r(3) });
    }

    #[test]
    fn identical_resend_is_idempotent() {
        let mut m = Matchmaker::new(0);
        run(&mut m, match_a(r(1), cfg(1)));
        // Same round, same config: answered again (dropped MatchB recovery).
        let out = run(&mut m, match_a(r(1), cfg(1)));
        assert!(matches!(out[0], Msg::MatchB { .. }));
        // Same round, different config: refused (rounds are single-proposer
        // so this only happens under faulty harnesses — still must refuse).
        let out = run(&mut m, match_a(r(1), cfg(9)));
        assert!(matches!(out[0], Msg::MatchNack { .. }));
    }

    #[test]
    fn garbage_collection() {
        let mut m = Matchmaker::new(0);
        for i in [0u64, 1, 2, 3] {
            run(&mut m, match_a(r(i), cfg(i)));
        }
        let out = run(&mut m, Msg::GarbageA { group: 0, round: r(2) });
        assert_eq!(out[0], Msg::GarbageB { group: 0, round: r(2) });
        assert_eq!(m.group_log_len(0), 2); // rounds 2 and 3 survive
        assert_eq!(m.gc_watermarks.get(&0), Some(&r(2)));
        // MatchA below the watermark is refused.
        let out = run(&mut m, match_a(r(1), cfg(1)));
        assert_eq!(out[0], Msg::MatchNack { group: 0, round: r(1), blocking: r(2) });
        // Watermark is monotone.
        run(&mut m, Msg::GarbageA { group: 0, round: r(1) });
        assert_eq!(m.gc_watermarks.get(&0), Some(&r(2)));
    }

    #[test]
    fn groups_are_independent() {
        // One shared matchmaker, two groups: refusals, H_i, and GC are all
        // per group. Group 7's round-5 answer must not block group 8's
        // round 0, and GC'ing group 7 must leave group 8's entries alone.
        let mut m = Matchmaker::new(0);
        let out = run(&mut m, Msg::MatchA { group: 7, round: r(5), config: cfg(5) });
        assert!(matches!(out[0], Msg::MatchB { group: 7, .. }));
        let out = run(&mut m, Msg::MatchA { group: 8, round: r(0), config: cfg(0) });
        match &out[0] {
            Msg::MatchB { group: 8, prior, .. } => assert!(prior.is_empty()),
            other => panic!("{other:?}"),
        }
        // Group 7's log does not leak into group 8's H_i.
        let out = run(&mut m, Msg::MatchA { group: 8, round: r(1), config: cfg(1) });
        match &out[0] {
            Msg::MatchB { group: 8, prior, .. } => {
                assert_eq!(prior.keys().copied().collect::<Vec<_>>(), vec![r(0)]);
            }
            other => panic!("{other:?}"),
        }
        // GC group 7 below round 6: group 8 keeps both entries and its
        // watermark stays unset.
        run(&mut m, Msg::GarbageA { group: 7, round: r(6) });
        assert_eq!(m.group_log_len(7), 0);
        assert_eq!(m.group_log_len(8), 2);
        assert_eq!(m.gc_watermarks.get(&8), None);
        // Group 8 still answers low rounds above its own (absent)
        // watermark; group 7 refuses below its watermark.
        let out = run(&mut m, Msg::MatchA { group: 7, round: r(2), config: cfg(2) });
        assert_eq!(out[0], Msg::MatchNack { group: 7, round: r(2), blocking: r(6) });
        assert_eq!(m.total_log_len(), 2);
    }

    #[test]
    fn match_b_reports_watermark() {
        let mut m = Matchmaker::new(0);
        run(&mut m, match_a(r(0), cfg(0)));
        run(&mut m, Msg::GarbageA { group: 0, round: r(1) });
        let out = run(&mut m, match_a(r(5), cfg(5)));
        match &out[0] {
            Msg::MatchB { gc_watermark, prior, .. } => {
                assert_eq!(*gc_watermark, Some(r(1)));
                assert!(prior.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stop_and_bootstrap() {
        let mut m = Matchmaker::new(0);
        run(&mut m, match_a(r(1), cfg(1)));
        let out = run(&mut m, Msg::StopA);
        match &out[0] {
            Msg::StopB { log, .. } => assert_eq!(log[&0].len(), 1),
            other => panic!("{other:?}"),
        }
        // Stopped: MatchA is silently dropped; StopA still answered.
        assert!(run(&mut m, match_a(r(2), cfg(2))).is_empty());
        assert!(matches!(run(&mut m, Msg::StopA)[0], Msg::StopB { .. }));

        // A standby bootstraps, but serves only after activation.
        let mut n = Matchmaker::new_standby(7);
        assert!(run(&mut n, match_a(r(3), cfg(3))).is_empty());
        let mut state: MmLog = BTreeMap::new();
        state.entry(0).or_default().insert(r(1), cfg(1));
        let out = run(
            &mut n,
            Msg::Bootstrap { log: state, gc_watermarks: BTreeMap::new(), generation: 1 },
        );
        assert_eq!(out[0], Msg::BootstrapAck);
        assert!(run(&mut n, match_a(r(3), cfg(3))).is_empty());
        // A stale activation (wrong generation) does not activate.
        run(&mut n, Msg::MatchmakersActivated { generation: 0, matchmakers: vec![7] });
        assert!(run(&mut n, match_a(r(3), cfg(3))).is_empty());
        run(&mut n, Msg::MatchmakersActivated { generation: 1, matchmakers: vec![7] });
        let out = run(&mut n, match_a(r(3), cfg(3)));
        match &out[0] {
            Msg::MatchB { prior, .. } => assert_eq!(prior.len(), 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn meta_paxos_acceptor_works_while_stopped() {
        let mut m = Matchmaker::new(0);
        run(&mut m, Msg::StopA);
        let out = run(&mut m, Msg::MetaPhase1A { round: r(0), generation: 0 });
        assert_eq!(out[0], Msg::MetaPhase1B { round: r(0), vr: None, vv: None });
        let out = run(&mut m, Msg::MetaPhase2A { round: r(0), generation: 0, matchmakers: vec![4, 5, 6] });
        assert_eq!(out[0], Msg::MetaPhase2B { round: r(0) });
        // Higher meta round sees the vote.
        let out = run(&mut m, Msg::MetaPhase1A { round: r(1), generation: 0 });
        assert_eq!(
            out[0],
            Msg::MetaPhase1B { round: r(1), vr: Some(r(0)), vv: Some(vec![4, 5, 6]) }
        );
        // Stale meta messages ignored.
        assert!(run(&mut m, Msg::MetaPhase1A { round: r(0), generation: 0 }).is_empty());
    }

    #[test]
    fn merge_stopped_logs_figure7() {
        // Figure 7 per group: union of the group's logs, entries below the
        // group's max watermark dropped.
        let glog = |entries: Vec<(Round, Configuration)>| -> MmLog {
            [(0u32, entries.into_iter().collect())].into_iter().collect()
        };
        let wm = |w: Round| -> BTreeMap<GroupId, Round> {
            [(0u32, w)].into_iter().collect()
        };
        let s0 = (glog(vec![(r(1), cfg(1)), (r(3), cfg(3))]), wm(r(1)));
        let s1 = (glog(vec![(r(2), cfg(2))]), wm(r(2)));
        let s2 = (glog(vec![(r(0), cfg(0)), (r(4), cfg(4))]), BTreeMap::new());
        let (merged, wms) = merge_stopped(&[s0, s1, s2]);
        assert_eq!(wms.get(&0), Some(&r(2)));
        let rounds: Vec<Round> = merged[&0].keys().copied().collect();
        assert_eq!(rounds, vec![r(2), r(3), r(4)]);
    }

    #[test]
    fn crash_recovery_restores_log_watermarks_and_lifecycle() {
        use crate::storage::MemStorage;
        let mut m = Matchmaker::new(0);
        m.attach_storage(Box::new(MemStorage::new()));
        for i in [0u64, 1, 2, 3] {
            run(&mut m, match_a(r(i), cfg(i)));
        }
        run(&mut m, Msg::GarbageA { group: 0, round: r(2) });
        run(&mut m, Msg::MetaPhase1A { round: r(0), generation: 0 });
        run(
            &mut m,
            Msg::MetaPhase2A { round: r(0), generation: 0, matchmakers: vec![4, 5, 6] },
        );
        // "kill -9": only the disk survives.
        let disk = m.take_storage().unwrap();
        let mut n = Matchmaker::new(0);
        n.attach_storage(disk);
        n.recover();
        assert_eq!(n.group_log_len(0), 2); // rounds 2 and 3, as pre-crash
        assert_eq!(n.gc_watermarks.get(&0), Some(&r(2)));
        assert!(n.active && !n.stopped);
        // Restored and pre-crash state render identically.
        assert_eq!(m.state_repr(), n.state_repr());
        // The restored matchmaker keeps its promises: a round below the
        // watermark is still refused, and the meta vote is still seen.
        let out = run(&mut n, match_a(r(1), cfg(1)));
        assert_eq!(out[0], Msg::MatchNack { group: 0, round: r(1), blocking: r(2) });
        let out = run(&mut n, Msg::MetaPhase1A { round: r(1), generation: 0 });
        assert_eq!(
            out[0],
            Msg::MetaPhase1B { round: r(1), vr: Some(r(0)), vv: Some(vec![4, 5, 6]) }
        );
    }

    #[test]
    fn merge_stopped_logs_multi_group() {
        // A busy group's watermark must not prune a quiet group's entries.
        let mut log_a: MmLog = BTreeMap::new();
        log_a.entry(0).or_default().insert(r(9), cfg(9));
        log_a.entry(1).or_default().insert(r(0), cfg(0));
        let wms_a: BTreeMap<GroupId, Round> = [(0u32, r(9))].into_iter().collect();
        let mut log_b: MmLog = BTreeMap::new();
        log_b.entry(0).or_default().insert(r(3), cfg(3));
        log_b.entry(1).or_default().insert(r(1), cfg(1));
        let (merged, wms) = merge_stopped(&[(log_a, wms_a), (log_b, BTreeMap::new())]);
        // Group 0: round 3 pruned by watermark 9; round 9 survives.
        assert_eq!(merged[&0].keys().copied().collect::<Vec<_>>(), vec![r(9)]);
        // Group 1: untouched by group 0's GC.
        assert_eq!(merged[&1].keys().copied().collect::<Vec<_>>(), vec![r(0), r(1)]);
        assert_eq!(wms.get(&1), None);
    }
}
