//! The matchmaker — the paper's central contribution (§3 Algorithm 1,
//! §5 Algorithm 4, §6).
//!
//! A matchmaker maintains a log `L` of configurations indexed by round.
//! `MatchA⟨i, C_i⟩` inserts `C_i` at entry `i` and returns every prior
//! configuration, *unless* the log already holds a configuration at a round
//! `≥ i` (or `i` is below the GC watermark), in which case the request is
//! refused — this refusal is exactly what makes the safety proof work: once
//! a matchmaker answers round `i`, it has promised never to answer any
//! round `≤ i` again.
//!
//! Matchmakers also:
//! * garbage-collect retired configurations (`GarbageA/B`, §5),
//! * support stop-and-copy reconfiguration of the matchmaker set itself
//!   (`StopA/B`, `Bootstrap`, §6), and
//! * double as Paxos acceptors for the meta-Paxos instance that chooses
//!   the next matchmaker set (§6) — processed even while stopped.

use crate::config::Configuration;
use crate::msg::Msg;
use crate::node::{Effects, Node, Timer};
use crate::round::Round;
use crate::{NodeId, Time};
use std::collections::BTreeMap;

/// A matchmaker node.
#[derive(Debug)]
pub struct Matchmaker {
    /// This node's id.
    pub id: NodeId,
    /// The configuration log `L`.
    pub log: BTreeMap<Round, Configuration>,
    /// GC watermark `w`: rounds `< w` are retired. `None` = nothing GC'd.
    pub gc_watermark: Option<Round>,
    /// Stopped by `StopA` (§6): refuses everything except `StopA` and the
    /// meta-Paxos messages.
    pub stopped: bool,
    /// New matchmakers are bootstrapped inactive and only start serving
    /// once the meta-Paxos chooses them (`MatchmakersActivated`).
    pub active: bool,
    /// Matchmaker-set generation (§6): generation g's members are the
    /// meta-Paxos acceptors for the instance that chooses generation g+1.
    pub generation: u64,

    // --- Meta-Paxos acceptor state, one single-decree instance per
    // generation: instance g (served by generation-g members) chooses the
    // generation-(g+1) set. Keyed by generation so votes can never leak
    // across instances, even when sets overlap. ---
    meta: BTreeMap<u64, MetaAcceptor>,
}

/// Per-instance meta-Paxos acceptor state.
#[derive(Debug, Default, Clone)]
struct MetaAcceptor {
    round: Option<Round>,
    vr: Option<Round>,
    vv: Option<Vec<NodeId>>,
}

impl Matchmaker {
    /// A member of the initial matchmaker set (active immediately).
    pub fn new(id: NodeId) -> Matchmaker {
        Matchmaker {
            id,
            log: BTreeMap::new(),
            gc_watermark: None,
            stopped: false,
            active: true,
            generation: 0,
            meta: BTreeMap::new(),
        }
    }

    /// A standby matchmaker: inactive until bootstrapped + activated (§6).
    pub fn new_standby(id: NodeId) -> Matchmaker {
        Matchmaker { active: false, ..Matchmaker::new(id) }
    }

    fn below_watermark(&self, r: Round) -> bool {
        matches!(self.gc_watermark, Some(w) if r < w)
    }
}

impl Node for Matchmaker {
    fn on_msg(&mut self, _now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        // Meta-Paxos duty survives stop (§6): the old matchmakers are the
        // acceptors that choose the next matchmaker set.
        match &msg {
            Msg::MetaPhase1A { round, generation } => {
                let inst = self.meta.entry(*generation).or_default();
                if matches!(inst.round, Some(r) if r > *round) {
                    return;
                }
                inst.round = Some(*round);
                fx.send(
                    from,
                    Msg::MetaPhase1B { round: *round, vr: inst.vr, vv: inst.vv.clone() },
                );
                return;
            }
            Msg::MetaPhase2A { round, generation, matchmakers } => {
                let inst = self.meta.entry(*generation).or_default();
                if matches!(inst.round, Some(r) if r > *round) {
                    return;
                }
                inst.round = Some(*round);
                inst.vr = Some(*round);
                inst.vv = Some(matchmakers.clone());
                fx.send(from, Msg::MetaPhase2B { round: *round });
                return;
            }
            // A stopped matchmaker may be re-used as a member of the *new*
            // set (§6 allows overlapping sets): Bootstrap resurrects it
            // with the merged state, inactive until activation. Meta-Paxos
            // state is untouched — instances are keyed by generation.
            Msg::Bootstrap { log, gc_watermark, generation } => {
                if *generation <= self.generation {
                    // Stale bootstrap from an abandoned reconfiguration of
                    // an earlier generation: refuse (no ack).
                    return;
                }
                self.log = log.clone();
                self.gc_watermark = *gc_watermark;
                self.generation = *generation;
                self.stopped = false;
                self.active = false;
                fx.send(from, Msg::BootstrapAck);
                return;
            }
            _ => {}
        }

        if self.stopped {
            // A stopped matchmaker answers StopA idempotently and nothing
            // else (§6).
            if matches!(msg, Msg::StopA) {
                fx.send(
                    from,
                    Msg::StopB { log: self.log.clone(), gc_watermark: self.gc_watermark },
                );
            }
            return;
        }

        match msg {
            // Algorithm 1 + Algorithm 4.
            Msg::MatchA { round, config } => {
                if !self.active {
                    return;
                }
                if self.below_watermark(round) {
                    fx.send(
                        from,
                        Msg::MatchNack { round, blocking: self.gc_watermark.unwrap() },
                    );
                    return;
                }
                // ∃ C_j at round j ≥ i (other than an identical re-send)?
                if let Some((&max_r, existing)) = self.log.iter().next_back() {
                    if max_r > round || (max_r == round && *existing != config) {
                        fx.send(from, Msg::MatchNack { round, blocking: max_r });
                        return;
                    }
                }
                // H_i = all configurations at rounds < i currently in L.
                let prior: BTreeMap<Round, Configuration> = self
                    .log
                    .range(..round)
                    .map(|(r, c)| (*r, c.clone()))
                    .collect();
                self.log.insert(round, config);
                fx.send(
                    from,
                    Msg::MatchB { round, gc_watermark: self.gc_watermark, prior },
                );
            }

            // Garbage collection (Algorithm 4): delete L[j] for all j < i,
            // raise the watermark.
            Msg::GarbageA { round } => {
                self.log = self.log.split_off(&round);
                if self.gc_watermark.map_or(true, |w| round > w) {
                    self.gc_watermark = Some(round);
                }
                fx.send(from, Msg::GarbageB { round });
            }

            // Matchmaker reconfiguration (§6).
            Msg::StopA => {
                self.stopped = true;
                fx.send(
                    from,
                    Msg::StopB { log: self.log.clone(), gc_watermark: self.gc_watermark },
                );
            }
            Msg::MatchmakersActivated { .. } => {
                self.active = true;
            }

            _ => {}
        }
    }

    fn on_timer(&mut self, _now: Time, _timer: Timer, _fx: &mut Effects) {}

    fn role(&self) -> &'static str {
        "matchmaker"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Merge the logs returned by `f+1` stopped matchmakers into the initial
/// state for the next matchmaker set (§6, Figure 7): union of the logs,
/// with every entry below the maximum watermark removed.
pub fn merge_stopped(
    states: &[(BTreeMap<Round, Configuration>, Option<Round>)],
) -> (BTreeMap<Round, Configuration>, Option<Round>) {
    let mut merged: BTreeMap<Round, Configuration> = BTreeMap::new();
    let mut wm: Option<Round> = None;
    for (log, w) in states {
        for (r, c) in log {
            merged.insert(*r, c.clone());
        }
        if let Some(w) = w {
            if wm.map_or(true, |cur| *w > cur) {
                wm = Some(*w);
            }
        }
    }
    if let Some(w) = wm {
        merged = merged.split_off(&w);
    }
    (merged, wm)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(n: u64) -> Round {
        Round { epoch: n, proposer: 0, seq: 0 }
    }

    fn cfg(id: u64) -> Configuration {
        Configuration::majority(id, vec![10 + id as NodeId, 11 + id as NodeId, 12 + id as NodeId])
    }

    fn run(m: &mut Matchmaker, msg: Msg) -> Vec<Msg> {
        let mut fx = Effects::new();
        m.on_msg(0, 99, msg, &mut fx);
        fx.msgs.into_iter().map(|(_, m)| m).collect()
    }

    #[test]
    fn figure3_execution() {
        // Reproduces the matchmaker execution of Figure 3.
        let mut m = Matchmaker::new(0);
        let out = run(&mut m, Msg::MatchA { round: r(0), config: cfg(0) });
        assert_eq!(
            out[0],
            Msg::MatchB { round: r(0), gc_watermark: None, prior: BTreeMap::new() }
        );
        let out = run(&mut m, Msg::MatchA { round: r(2), config: cfg(2) });
        match &out[0] {
            Msg::MatchB { prior, .. } => {
                assert_eq!(prior.len(), 1);
                assert_eq!(prior[&r(0)], cfg(0));
            }
            other => panic!("{other:?}"),
        }
        let out = run(&mut m, Msg::MatchA { round: r(3), config: cfg(3) });
        match &out[0] {
            Msg::MatchB { prior, .. } => {
                assert_eq!(prior.len(), 2);
                assert!(prior.contains_key(&r(0)) && prior.contains_key(&r(2)));
            }
            other => panic!("{other:?}"),
        }
        // MatchA(1, C1) now refused: log holds rounds ≥ 1.
        let out = run(&mut m, Msg::MatchA { round: r(1), config: cfg(1) });
        assert_eq!(out[0], Msg::MatchNack { round: r(1), blocking: r(3) });
    }

    #[test]
    fn identical_resend_is_idempotent() {
        let mut m = Matchmaker::new(0);
        run(&mut m, Msg::MatchA { round: r(1), config: cfg(1) });
        // Same round, same config: answered again (dropped MatchB recovery).
        let out = run(&mut m, Msg::MatchA { round: r(1), config: cfg(1) });
        assert!(matches!(out[0], Msg::MatchB { .. }));
        // Same round, different config: refused (rounds are single-proposer
        // so this only happens under faulty harnesses — still must refuse).
        let out = run(&mut m, Msg::MatchA { round: r(1), config: cfg(9) });
        assert!(matches!(out[0], Msg::MatchNack { .. }));
    }

    #[test]
    fn garbage_collection() {
        let mut m = Matchmaker::new(0);
        for i in [0u64, 1, 2, 3] {
            run(&mut m, Msg::MatchA { round: r(i), config: cfg(i) });
        }
        let out = run(&mut m, Msg::GarbageA { round: r(2) });
        assert_eq!(out[0], Msg::GarbageB { round: r(2) });
        assert_eq!(m.log.len(), 2); // rounds 2 and 3 survive
        assert_eq!(m.gc_watermark, Some(r(2)));
        // MatchA below the watermark is refused.
        let out = run(&mut m, Msg::MatchA { round: r(1), config: cfg(1) });
        assert_eq!(out[0], Msg::MatchNack { round: r(1), blocking: r(2) });
        // Watermark is monotone.
        run(&mut m, Msg::GarbageA { round: r(1) });
        assert_eq!(m.gc_watermark, Some(r(2)));
    }

    #[test]
    fn match_b_reports_watermark() {
        let mut m = Matchmaker::new(0);
        run(&mut m, Msg::MatchA { round: r(0), config: cfg(0) });
        run(&mut m, Msg::GarbageA { round: r(1) });
        let out = run(&mut m, Msg::MatchA { round: r(5), config: cfg(5) });
        match &out[0] {
            Msg::MatchB { gc_watermark, prior, .. } => {
                assert_eq!(*gc_watermark, Some(r(1)));
                assert!(prior.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stop_and_bootstrap() {
        let mut m = Matchmaker::new(0);
        run(&mut m, Msg::MatchA { round: r(1), config: cfg(1) });
        let out = run(&mut m, Msg::StopA);
        match &out[0] {
            Msg::StopB { log, .. } => assert_eq!(log.len(), 1),
            other => panic!("{other:?}"),
        }
        // Stopped: MatchA is silently dropped; StopA still answered.
        assert!(run(&mut m, Msg::MatchA { round: r(2), config: cfg(2) }).is_empty());
        assert!(matches!(run(&mut m, Msg::StopA)[0], Msg::StopB { .. }));

        // A standby bootstraps, but serves only after activation.
        let mut n = Matchmaker::new_standby(7);
        assert!(run(&mut n, Msg::MatchA { round: r(3), config: cfg(3) }).is_empty());
        let mut state = BTreeMap::new();
        state.insert(r(1), cfg(1));
        let out = run(&mut n, Msg::Bootstrap { log: state, gc_watermark: None, generation: 1 });
        assert_eq!(out[0], Msg::BootstrapAck);
        assert!(run(&mut n, Msg::MatchA { round: r(3), config: cfg(3) }).is_empty());
        run(&mut n, Msg::MatchmakersActivated { matchmakers: vec![7] });
        let out = run(&mut n, Msg::MatchA { round: r(3), config: cfg(3) });
        match &out[0] {
            Msg::MatchB { prior, .. } => assert_eq!(prior.len(), 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn meta_paxos_acceptor_works_while_stopped() {
        let mut m = Matchmaker::new(0);
        run(&mut m, Msg::StopA);
        let out = run(&mut m, Msg::MetaPhase1A { round: r(0), generation: 0 });
        assert_eq!(out[0], Msg::MetaPhase1B { round: r(0), vr: None, vv: None });
        let out = run(&mut m, Msg::MetaPhase2A { round: r(0), generation: 0, matchmakers: vec![4, 5, 6] });
        assert_eq!(out[0], Msg::MetaPhase2B { round: r(0) });
        // Higher meta round sees the vote.
        let out = run(&mut m, Msg::MetaPhase1A { round: r(1), generation: 0 });
        assert_eq!(
            out[0],
            Msg::MetaPhase1B { round: r(1), vr: Some(r(0)), vv: Some(vec![4, 5, 6]) }
        );
        // Stale meta messages ignored.
        assert!(run(&mut m, Msg::MetaPhase1A { round: r(0), generation: 0 }).is_empty());
    }

    #[test]
    fn merge_stopped_logs_figure7() {
        // Figure 7: union of logs, entries below the max watermark dropped.
        let s0 = (
            [(r(1), cfg(1)), (r(3), cfg(3))].into_iter().collect(),
            Some(r(1)),
        );
        let s1 = (
            [(r(2), cfg(2))].into_iter().collect(),
            Some(r(2)),
        );
        let s2 = ([(r(0), cfg(0)), (r(4), cfg(4))].into_iter().collect(), None);
        let (merged, wm) = merge_stopped(&[s0, s1, s2]);
        assert_eq!(wm, Some(r(2)));
        let rounds: Vec<Round> = merged.keys().copied().collect();
        assert_eq!(rounds, vec![r(2), r(3), r(4)]);
    }
}
