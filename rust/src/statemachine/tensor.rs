//! The tensor state machine: the three-layer proof point.
//!
//! Commands are `D`-dimensional f32 vectors; the replicated state is a
//! `D×D` f32 matrix. Applying a batch `C ∈ R^{B×D}` computes (in the
//! AOT-compiled JAX program, whose hot matmul is the L1 Pallas kernel):
//!
//! ```text
//! M  = C · W                 # Pallas kernel (MXU-shaped tiled matmul)
//! S' = decay · S + Mᵀ · C    # rank-B state update
//! d  = rowsum(M ⊙ C)         # per-command digest (the client reply)
//! ```
//!
//! `W` is a fixed mixing matrix generated from the same integer pattern on
//! both sides (see `python/compile/kernels/ref.py`), `decay = 0.5`. All
//! replicas run the identical computation, so they stay bit-for-bit in
//! sync — the digest doubles as a cross-replica consistency check.
//!
//! ## Backends
//!
//! * **Reference** (always available): [`reference_step`] in pure Rust —
//!   the same math, deterministic, dependency-free. Used whenever the
//!   `pjrt` feature is off or the AOT artifacts are missing, so the
//!   tensor path (and the Phase 2 batching experiments built on it) runs
//!   everywhere.
//! * **PJRT** (`--features pjrt` + `make artifacts`): executes the
//!   compiled `apply_batch_b{1,8,32}.hlo.txt` artifacts through the XLA
//!   PJRT CPU client ([`crate::runtime`]). Python is never on the request
//!   path.
//!
//! Note the batch semantics: `decay` is applied once per *batch*, so the
//! state after `apply_many([c1, c2])` intentionally differs from two
//! single-command applies. Replicas execute identical chosen batches in
//! identical order, so they remain bitwise consistent for any batching
//! configuration.

use super::StateMachine;
use anyhow::Result;
#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;

/// State dimension. Must match `python/compile/model.py::D`.
pub const D: usize = 16;
/// Batch sizes with compiled artifacts. Requests are padded up to the
/// nearest size. Must match `python/compile/aot.py::BATCH_SIZES`.
pub const BATCH_SIZES: [usize; 3] = [1, 8, 32];
/// State decay per batch. Must match `python/compile/model.py::DECAY`.
pub const DECAY: f32 = 0.5;

/// How a loaded [`TensorStateMachine`] executes a batch.
enum Backend {
    /// Pure-Rust evaluator ([`reference_step`]).
    Reference,
    /// Compiled AOT artifacts executed via PJRT, one program per batch
    /// size.
    #[cfg(feature = "pjrt")]
    Pjrt(BTreeMap<usize, crate::runtime::Program>),
}

/// Replicated tensor state machine (reference or XLA-backed).
pub struct TensorStateMachine {
    // NOTE on Send (see unsafe impl below): with the `pjrt` feature the
    // xla crate's handles hold `Rc`s and raw PJRT pointers, so the
    // compiler can't prove Send. We only ever *move* the whole state
    // machine into a single owning thread (replica event loop); the Rcs
    // are never shared across threads, and the PJRT CPU client supports
    // use from any one thread at a time. The reference backend is
    // trivially Send.
    state: Vec<f32>, // D*D row-major
    backend: Backend,
    /// Batches applied (metrics).
    pub batches: u64,
    /// Commands applied (metrics).
    pub commands: u64,
}

// SAFETY: all backend handles inside are owned exclusively by this struct
// and are only accessed by the single thread that owns it at any given
// time (any Rc reference graph is fully contained within the struct, so
// moving the struct moves every strong count with it).
unsafe impl Send for TensorStateMachine {}

impl TensorStateMachine {
    /// Load the state machine with a zero state. With `--features pjrt`
    /// and built artifacts (`make artifacts`) this compiles and uses the
    /// AOT programs; otherwise it falls back to the pure-Rust reference
    /// backend with identical semantics.
    pub fn load() -> Result<TensorStateMachine> {
        #[cfg(feature = "pjrt")]
        {
            if crate::runtime::artifacts_available() {
                use anyhow::Context as _;
                let engine = crate::runtime::Engine::cpu()?;
                let dir = crate::runtime::artifacts_dir();
                let mut programs = BTreeMap::new();
                for b in BATCH_SIZES {
                    let path = dir.join(format!("apply_batch_b{b}.hlo.txt"));
                    let program = engine.load_hlo_text(&path).with_context(|| {
                        format!("load artifact for batch size {b} — run `make artifacts`")
                    })?;
                    programs.insert(b, program);
                }
                return Ok(TensorStateMachine {
                    state: vec![0.0; D * D],
                    backend: Backend::Pjrt(programs),
                    batches: 0,
                    commands: 0,
                });
            }
        }
        Ok(TensorStateMachine {
            state: vec![0.0; D * D],
            backend: Backend::Reference,
            batches: 0,
            commands: 0,
        })
    }

    /// Which backend executes batches: `"reference"` or `"pjrt"`.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Reference => "reference",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Decode a command payload into a `D`-vector (f32 LE, zero-padded).
    pub fn decode(payload: &[u8]) -> Vec<f32> {
        let mut v = vec![0f32; D];
        for (i, chunk) in payload.chunks_exact(4).take(D).enumerate() {
            v[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        v
    }

    /// Encode a command vector into a payload.
    pub fn encode(cmd: &[f32]) -> Vec<u8> {
        cmd.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    /// Run one compiled/padded batch step of size `b` over `batch`
    /// (row-major `b × D`), updating the state and returning all `b`
    /// digests.
    fn step(&mut self, b: usize, batch: &[f32]) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Reference => {
                let rows: Vec<Vec<f32>> =
                    (0..b).map(|r| batch[r * D..(r + 1) * D].to_vec()).collect();
                let (state, digests) = reference_step(&self.state, &rows);
                self.state = state;
                Ok(digests)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(programs) => {
                let program = &programs[&b];
                let outputs = program.run_f32(&[
                    (&self.state, &[D as i64, D as i64]),
                    (batch, &[b as i64, D as i64]),
                ])?;
                anyhow::ensure!(outputs.len() == 2, "expected (state, digest) outputs");
                self.state = outputs[0].clone();
                Ok(outputs[1].clone())
            }
        }
    }

    /// Apply a batch of decoded commands; returns per-command digests.
    /// Pads to the nearest compiled batch size with zero commands (zero
    /// commands contribute a zero update, preserving semantics).
    pub fn apply_batch(&mut self, cmds: &[Vec<f32>]) -> Result<Vec<f32>> {
        if cmds.is_empty() {
            return Ok(Vec::new());
        }
        let mut digests = Vec::with_capacity(cmds.len());
        let mut offset = 0;
        while offset < cmds.len() {
            let remaining = cmds.len() - offset;
            // Full chunks of the largest size; the tail is padded up to the
            // smallest compiled size that fits it (zero-pad preserves
            // semantics: zero commands contribute nothing).
            let b = BATCH_SIZES
                .iter()
                .find(|&&b| b >= remaining)
                .or(BATCH_SIZES.last())
                .copied()
                .unwrap();
            let take = b.min(remaining);
            let mut batch = vec![0f32; b * D];
            for (i, c) in cmds[offset..offset + take].iter().enumerate() {
                batch[i * D..(i + 1) * D].copy_from_slice(&c[..D]);
            }
            let step_digests = self.step(b, &batch)?;
            digests.extend_from_slice(&step_digests[..take]);
            self.batches += 1;
            self.commands += take as u64;
            offset += take;
        }
        Ok(digests)
    }

    /// Current state (tests).
    pub fn state(&self) -> &[f32] {
        &self.state
    }
}

impl StateMachine for TensorStateMachine {
    fn apply(&mut self, payload: &[u8]) -> Vec<u8> {
        let cmd = Self::decode(payload);
        match self.apply_batch(&[cmd]) {
            Ok(digests) => digests[0].to_le_bytes().to_vec(),
            Err(e) => format!("ERR {e}").into_bytes(),
        }
    }

    /// Batch-native execution: one XLA (or reference) invocation covers
    /// the whole batch — this is the path the Phase 2 batching tentpole
    /// routes replica execution through.
    fn apply_many(&mut self, payloads: &[&[u8]]) -> Vec<Vec<u8>> {
        let cmds: Vec<Vec<f32>> = payloads.iter().map(|p| Self::decode(p)).collect();
        match self.apply_batch(&cmds) {
            Ok(digests) => digests.iter().map(|d| d.to_le_bytes().to_vec()).collect(),
            Err(e) => {
                let msg = format!("ERR {e}").into_bytes();
                payloads.iter().map(|_| msg.clone()).collect()
            }
        }
    }

    fn digest(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for x in &self.state {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        h
    }

    /// Read-only query. Empty payload (or anything shorter than 8
    /// bytes): the FNV digest of the full state, LE u64 — the cheap
    /// "model version" probe. An 8-byte LE row index: that state row as
    /// `D` little-endian f32s — a read of one row of the replicated
    /// tensor without a round through the log.
    fn query(&self, payload: &[u8]) -> Vec<u8> {
        if payload.len() >= 8 {
            let row = u64::from_le_bytes(payload[..8].try_into().unwrap()) as usize % D;
            return self.state[row * D..(row + 1) * D]
                .iter()
                .flat_map(|x| x.to_le_bytes())
                .collect();
        }
        self.digest().to_le_bytes().to_vec()
    }

    /// The `D×D` f32 state, little-endian (backend-independent: a
    /// reference-backend snapshot restores into a PJRT-backed replica and
    /// vice versa).
    fn snapshot(&self) -> Vec<u8> {
        self.state.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    fn restore(&mut self, snap: &[u8]) -> bool {
        if snap.len() != D * D * 4 {
            return false;
        }
        self.state = snap
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        true
    }

    fn name(&self) -> &'static str {
        "tensor"
    }
}

/// The fixed mixing matrix `W`, identical to the Python definition:
/// `W[i,j] = ((i*31 + j*17) % 7 - 3) / 4` — exactly representable in f32
/// on both sides. Used by tests to cross-check the artifact numerics.
pub fn mixing_matrix() -> Vec<f32> {
    let mut w = vec![0f32; D * D];
    for i in 0..D {
        for j in 0..D {
            w[i * D + j] = (((i * 31 + j * 17) % 7) as f32 - 3.0) / 4.0;
        }
    }
    w
}

/// Pure-Rust reference of one batch step (the reference backend, and the
/// oracle for artifact tests; mirrors `python/compile/kernels/ref.py`).
pub fn reference_step(state: &[f32], cmds: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
    let w = mixing_matrix();
    let b = cmds.len();
    // M = C · W
    let mut m = vec![0f32; b * D];
    for r in 0..b {
        for j in 0..D {
            let mut acc = 0f32;
            for k in 0..D {
                acc += cmds[r][k] * w[k * D + j];
            }
            m[r * D + j] = acc;
        }
    }
    // S' = decay·S + Mᵀ·C
    let mut s = vec![0f32; D * D];
    for i in 0..D {
        for j in 0..D {
            let mut acc = DECAY * state[i * D + j];
            for r in 0..b {
                acc += m[r * D + i] * cmds[r][j];
            }
            s[i * D + j] = acc;
        }
    }
    // d = rowsum(M ⊙ C)
    let mut d = vec![0f32; b];
    for r in 0..b {
        d[r] = (0..D).map(|j| m[r * D + j] * cmds[r][j]).sum();
    }
    (s, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd(seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..D).map(|_| (rng.gen_range(17) as f32 - 8.0) / 4.0).collect()
    }

    #[test]
    fn codec_roundtrip() {
        let c = cmd(3);
        let p = TensorStateMachine::encode(&c);
        assert_eq!(TensorStateMachine::decode(&p), c);
        // Short payloads zero-pad.
        assert_eq!(TensorStateMachine::decode(&p[..8])[2..], vec![0f32; D - 2]);
    }

    #[test]
    fn mixing_matrix_pattern() {
        let w = mixing_matrix();
        assert_eq!(w.len(), D * D);
        assert_eq!(w[0], ((0 % 7) as f32 - 3.0) / 4.0);
        assert!(w.iter().all(|x| (-0.75..=0.75).contains(x)));
    }

    #[test]
    fn reference_step_zero_cmds_decay_only() {
        let state: Vec<f32> = (0..D * D).map(|i| i as f32).collect();
        let (s, d) = reference_step(&state, &[vec![0f32; D]]);
        for i in 0..D * D {
            assert_eq!(s[i], state[i] * DECAY);
        }
        assert_eq!(d, vec![0.0]);
    }

    #[test]
    fn loaded_backend_matches_reference() {
        // With the default (reference) backend this is an identity check;
        // with `--features pjrt` + artifacts it cross-checks the compiled
        // program against the Rust oracle.
        let mut sm = TensorStateMachine::load().unwrap();
        let cmds: Vec<Vec<f32>> = (0..8).map(|i| cmd(100 + i)).collect();
        let (ref_state, ref_digest) = reference_step(&vec![0f32; D * D], &cmds);
        let digests = sm.apply_batch(&cmds).unwrap();
        for (a, b) in digests.iter().zip(&ref_digest) {
            assert!((a - b).abs() < 1e-3, "digest {a} vs {b}");
        }
        for (a, b) in sm.state().iter().zip(&ref_state) {
            assert!((a - b).abs() < 1e-3, "state {a} vs {b}");
        }
        assert_eq!(sm.batches, 1);
        assert_eq!(sm.commands, 8);
    }

    #[test]
    fn replicas_stay_in_sync() {
        let mut a = TensorStateMachine::load().unwrap();
        let mut b = TensorStateMachine::load().unwrap();
        for i in 0..20 {
            let payload = TensorStateMachine::encode(&cmd(i));
            let ra = a.apply(&payload);
            let rb = b.apply(&payload);
            assert_eq!(ra, rb);
        }
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.commands, 20);
    }

    #[test]
    fn batch_padding_equals_sequential() {
        // Applying 5 commands pads up to the b=8 program; all 5 digests
        // come back and the padding rows contribute nothing.
        let mut sm = TensorStateMachine::load().unwrap();
        let cmds: Vec<Vec<f32>> = (0..5).map(cmd).collect();
        let digests = sm.apply_batch(&cmds).unwrap();
        assert_eq!(digests.len(), 5);
        let (_, ref_digest) = reference_step(&vec![0f32; D * D], &cmds);
        for (a, b) in digests.iter().zip(&ref_digest) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn apply_many_is_batch_native() {
        // apply_many(batch) == apply_batch(batch): one decay per batch,
        // per-command digests in order.
        let mut via_trait = TensorStateMachine::load().unwrap();
        let mut via_batch = TensorStateMachine::load().unwrap();
        let cmds: Vec<Vec<f32>> = (0..6).map(|i| cmd(50 + i)).collect();
        let payloads: Vec<Vec<u8>> =
            cmds.iter().map(|c| TensorStateMachine::encode(c)).collect();
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        let results = StateMachine::apply_many(&mut via_trait, &refs);
        let digests = via_batch.apply_batch(&cmds).unwrap();
        assert_eq!(results.len(), 6);
        for (r, d) in results.iter().zip(&digests) {
            assert_eq!(r.as_slice(), d.to_le_bytes().as_slice());
        }
        assert_eq!(via_trait.digest(), StateMachine::digest(&via_batch));
        // Batch-native: 6 commands, ONE padded batch invocation.
        assert_eq!(via_trait.batches, 1);
    }

    #[test]
    fn snapshot_restore_preserves_trajectory() {
        let mut a = TensorStateMachine::load().unwrap();
        for i in 0..5 {
            a.apply(&TensorStateMachine::encode(&cmd(i)));
        }
        let snap = StateMachine::snapshot(&a);
        let mut b = TensorStateMachine::load().unwrap();
        assert!(StateMachine::restore(&mut b, &snap));
        assert_eq!(StateMachine::digest(&a), StateMachine::digest(&b));
        // Identical future behavior after restore.
        let p = TensorStateMachine::encode(&cmd(99));
        assert_eq!(a.apply(&p), b.apply(&p));
        // Wrong-size snapshots are refused.
        assert!(!StateMachine::restore(&mut b, &snap[..8]));
    }

    #[test]
    fn query_digest_and_row_reads() {
        let mut sm = TensorStateMachine::load().unwrap();
        sm.apply(&TensorStateMachine::encode(&cmd(3)));
        // Empty payload: the state digest, LE u64, and no mutation.
        let d0 = StateMachine::digest(&sm);
        assert_eq!(sm.query(&[]), d0.to_le_bytes().to_vec());
        assert_eq!(StateMachine::digest(&sm), d0);
        // Row read: D little-endian f32s matching the state slice.
        let row = 2u64;
        let bytes = sm.query(&row.to_le_bytes());
        assert_eq!(bytes.len(), D * 4);
        let got: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got.as_slice(), &sm.state()[2 * D..3 * D]);
        // Out-of-range rows wrap instead of panicking.
        let huge = (D as u64 + 2).to_le_bytes();
        assert_eq!(sm.query(&huge), sm.query(&2u64.to_le_bytes()));
    }

    #[test]
    fn large_input_chunks_by_32() {
        let mut sm = TensorStateMachine::load().unwrap();
        let cmds: Vec<Vec<f32>> = (0..70).map(cmd).collect();
        let digests = sm.apply_batch(&cmds).unwrap();
        assert_eq!(digests.len(), 70);
        // 32 + 32 + 6→8-padded = 3 batch invocations.
        assert_eq!(sm.batches, 3);
        assert_eq!(sm.commands, 70);
    }
}
