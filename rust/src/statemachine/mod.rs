//! Replicated state machines.
//!
//! The paper's evaluation uses a one-byte no-op state machine ([`Noop`]);
//! we additionally provide a key-value store, a register, a counter, and —
//! proving the three-layer stack — [`tensor::TensorStateMachine`], which
//! executes batched commands through the AOT-compiled JAX/Pallas program
//! loaded via PJRT ([`crate::runtime`]).

pub mod tensor;

pub use tensor::TensorStateMachine;

/// A deterministic application state machine. Replicas apply chosen
/// commands in log order; determinism keeps replicas in sync.
pub trait StateMachine: Send {
    /// Apply one command, returning the result sent back to the client.
    fn apply(&mut self, payload: &[u8]) -> Vec<u8>;

    /// Apply a batch of commands in order, returning one result per
    /// command (Phase 2 batching: replicas unpack a `Value::Batch` and
    /// execute it through this entry point). The default applies commands
    /// one by one; batch-native machines ([`TensorStateMachine`])
    /// override it to amortize per-invocation overhead across the batch.
    fn apply_many(&mut self, payloads: &[&[u8]]) -> Vec<Vec<u8>> {
        payloads.iter().map(|p| self.apply(p)).collect()
    }

    /// Answer a read-only query against the current state **without
    /// mutating it**. This is the replica-served linearizable-read
    /// entry point ([`crate::msg::Msg::Read`]): the replica resolves a
    /// read index, waits until its applied prefix covers it, then
    /// answers from here — the query never enters the chosen log.
    /// Implementations must match the read-only subset of
    /// [`StateMachine::apply`] (a kv `get` query returns exactly what
    /// the same `get` payload would return through `apply`), so the
    /// all-through-Phase-2 baseline and the leased path agree. Default:
    /// empty (the no-op machine has no readable state).
    fn query(&self, _payload: &[u8]) -> Vec<u8> {
        Vec::new()
    }

    /// A digest of the current state, used by tests to check replica
    /// convergence. Default: empty (stateless machines).
    fn digest(&self) -> u64 {
        0
    }

    /// Serialize the full application state. The replica state-retention
    /// subsystem snapshots the state machine periodically so the chosen
    /// log below the snapshot watermark can be truncated, and ships the
    /// snapshot to lagging or freshly joined replicas
    /// ([`crate::msg::Msg::SnapshotResp`]). Must be deterministic:
    /// `restore(snapshot())` on a fresh machine yields an equivalent
    /// machine (equal [`StateMachine::digest`], identical future
    /// behavior). Default: empty (stateless machines).
    fn snapshot(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state previously produced by [`StateMachine::snapshot`].
    /// Returns `false` (leaving the state unchanged where possible) if
    /// the bytes are malformed or from a different machine type — a
    /// replica refuses to install such a snapshot. Default: accepts only
    /// the empty snapshot (stateless machines).
    fn restore(&mut self, snap: &[u8]) -> bool {
        snap.is_empty()
    }

    /// Role name for configs/logs (`statemachine::by_name` key).
    fn name(&self) -> &'static str;
}

/// The paper's no-op state machine: every command is a one-byte no-op.
pub struct Noop;

impl StateMachine for Noop {
    fn apply(&mut self, _payload: &[u8]) -> Vec<u8> {
        Vec::new()
    }
    fn name(&self) -> &'static str {
        "noop"
    }
}

/// FNV-1a, used for state digests.
fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = if h == 0 { 0xcbf29ce484222325 } else { h };
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A key-value store. Payload format:
/// `s<klen:u8><key><value>` = set, `g<klen:u8><key>` = get,
/// `d<klen:u8><key>` = delete. Malformed payloads return `b"ERR"`.
pub struct KvStore {
    map: std::collections::BTreeMap<Vec<u8>, Vec<u8>>,
}

impl KvStore {
    pub fn new() -> KvStore {
        KvStore { map: std::collections::BTreeMap::new() }
    }

    /// Encode a `set` command.
    pub fn enc_set(key: &[u8], value: &[u8]) -> Vec<u8> {
        let mut p = vec![b's', key.len() as u8];
        p.extend_from_slice(key);
        p.extend_from_slice(value);
        p
    }

    /// Encode a `get` command.
    pub fn enc_get(key: &[u8]) -> Vec<u8> {
        let mut p = vec![b'g', key.len() as u8];
        p.extend_from_slice(key);
        p
    }

    /// Encode a `delete` command.
    pub fn enc_del(key: &[u8]) -> Vec<u8> {
        let mut p = vec![b'd', key.len() as u8];
        p.extend_from_slice(key);
        p
    }

    fn parse<'a>(payload: &'a [u8]) -> Option<(u8, &'a [u8], &'a [u8])> {
        if payload.len() < 2 {
            return None;
        }
        let op = payload[0];
        let klen = payload[1] as usize;
        if payload.len() < 2 + klen {
            return None;
        }
        let key = &payload[2..2 + klen];
        let rest = &payload[2 + klen..];
        Some((op, key, rest))
    }
}

impl Default for KvStore {
    fn default() -> Self {
        Self::new()
    }
}

impl StateMachine for KvStore {
    fn apply(&mut self, payload: &[u8]) -> Vec<u8> {
        match KvStore::parse(payload) {
            Some((b's', key, value)) => {
                self.map.insert(key.to_vec(), value.to_vec());
                b"OK".to_vec()
            }
            Some((b'g', key, _)) => self.map.get(key).cloned().unwrap_or_default(),
            Some((b'd', key, _)) => {
                self.map.remove(key);
                b"OK".to_vec()
            }
            _ => b"ERR".to_vec(),
        }
    }

    /// Read-only queries: `g<klen><key>` returns the value (mirroring
    /// the `apply` get path); mutating or malformed payloads are `ERR`.
    fn query(&self, payload: &[u8]) -> Vec<u8> {
        match KvStore::parse(payload) {
            Some((b'g', key, _)) => self.map.get(key).cloned().unwrap_or_default(),
            _ => b"ERR".to_vec(),
        }
    }

    fn digest(&self) -> u64 {
        let mut h = 0u64;
        for (k, v) in &self.map {
            h = fnv1a(h, k);
            h = fnv1a(h, v);
        }
        h
    }

    fn snapshot(&self) -> Vec<u8> {
        let mut e = crate::codec::Enc::new();
        e.u32(self.map.len() as u32);
        for (k, v) in &self.map {
            e.bytes(k);
            e.bytes(v);
        }
        e.buf
    }

    fn restore(&mut self, snap: &[u8]) -> bool {
        let mut d = crate::codec::Dec::new(snap);
        let Ok(n) = d.u32() else {
            return false;
        };
        let mut map = std::collections::BTreeMap::new();
        for _ in 0..n {
            let (Ok(k), Ok(v)) = (d.bytes(), d.bytes()) else {
                return false;
            };
            map.insert(k, v);
        }
        if !d.done() {
            return false;
        }
        self.map = map;
        true
    }

    fn name(&self) -> &'static str {
        "kv"
    }
}

/// A single register: every command overwrites the value; the reply is the
/// *previous* value (test-and-set flavor).
pub struct Register {
    value: Vec<u8>,
}

impl Register {
    pub fn new() -> Register {
        Register { value: Vec::new() }
    }
}

impl Default for Register {
    fn default() -> Self {
        Self::new()
    }
}

impl StateMachine for Register {
    fn apply(&mut self, payload: &[u8]) -> Vec<u8> {
        std::mem::replace(&mut self.value, payload.to_vec())
    }
    /// Read-only query: the current value (payload ignored).
    fn query(&self, _payload: &[u8]) -> Vec<u8> {
        self.value.clone()
    }
    fn digest(&self) -> u64 {
        fnv1a(0, &self.value)
    }
    fn snapshot(&self) -> Vec<u8> {
        self.value.clone()
    }
    fn restore(&mut self, snap: &[u8]) -> bool {
        self.value = snap.to_vec();
        true
    }
    fn name(&self) -> &'static str {
        "register"
    }
}

/// A counter: payload is an i64 delta (little-endian); reply is the new
/// total.
pub struct Counter {
    total: i64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter { total: 0 }
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

impl StateMachine for Counter {
    fn apply(&mut self, payload: &[u8]) -> Vec<u8> {
        let mut buf = [0u8; 8];
        let n = payload.len().min(8);
        buf[..n].copy_from_slice(&payload[..n]);
        self.total = self.total.wrapping_add(i64::from_le_bytes(buf));
        self.total.to_le_bytes().to_vec()
    }
    /// Read-only query: the current total (payload ignored) — identical
    /// to what a delta-0 `apply` would return, so leased reads and the
    /// through-the-log baseline agree.
    fn query(&self, _payload: &[u8]) -> Vec<u8> {
        self.total.to_le_bytes().to_vec()
    }
    fn digest(&self) -> u64 {
        self.total as u64
    }
    fn snapshot(&self) -> Vec<u8> {
        self.total.to_le_bytes().to_vec()
    }
    fn restore(&mut self, snap: &[u8]) -> bool {
        let Ok(bytes) = <[u8; 8]>::try_from(snap) else {
            return false;
        };
        self.total = i64::from_le_bytes(bytes);
        true
    }
    fn name(&self) -> &'static str {
        "counter"
    }
}

/// Construct a state machine by name (deployment config `state_machine`).
pub fn by_name(name: &str) -> Option<Box<dyn StateMachine>> {
    match name {
        "noop" => Some(Box::new(Noop)),
        "kv" => Some(Box::new(KvStore::new())),
        "register" => Some(Box::new(Register::new())),
        "counter" => Some(Box::new(Counter::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop() {
        let mut sm = Noop;
        assert!(sm.apply(b"x").is_empty());
        assert_eq!(sm.digest(), 0);
    }

    #[test]
    fn kv_set_get_del() {
        let mut kv = KvStore::new();
        assert_eq!(kv.apply(&KvStore::enc_set(b"k", b"v1")), b"OK");
        assert_eq!(kv.apply(&KvStore::enc_get(b"k")), b"v1");
        assert_eq!(kv.apply(&KvStore::enc_set(b"k", b"v2")), b"OK");
        assert_eq!(kv.apply(&KvStore::enc_get(b"k")), b"v2");
        assert_eq!(kv.apply(&KvStore::enc_del(b"k")), b"OK");
        assert!(kv.apply(&KvStore::enc_get(b"k")).is_empty());
        assert_eq!(kv.apply(b""), b"ERR");
        assert_eq!(kv.apply(&[b's', 200, 1]), b"ERR");
    }

    #[test]
    fn kv_digest_tracks_state() {
        let mut a = KvStore::new();
        let mut b = KvStore::new();
        a.apply(&KvStore::enc_set(b"x", b"1"));
        b.apply(&KvStore::enc_set(b"x", b"1"));
        assert_eq!(a.digest(), b.digest());
        b.apply(&KvStore::enc_set(b"y", b"2"));
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn register_swaps() {
        let mut r = Register::new();
        assert!(r.apply(b"a").is_empty());
        assert_eq!(r.apply(b"b"), b"a");
        assert_eq!(r.apply(b"c"), b"b");
    }

    #[test]
    fn counter_accumulates() {
        let mut c = Counter::new();
        assert_eq!(c.apply(&5i64.to_le_bytes()), 5i64.to_le_bytes());
        assert_eq!(c.apply(&(-2i64).to_le_bytes()), 3i64.to_le_bytes());
        assert_eq!(c.digest(), 3);
    }

    #[test]
    fn apply_many_default_matches_sequential() {
        let mut a = KvStore::new();
        let mut b = KvStore::new();
        let cmds = vec![
            KvStore::enc_set(b"x", b"1"),
            KvStore::enc_set(b"y", b"2"),
            KvStore::enc_get(b"x"),
        ];
        let refs: Vec<&[u8]> = cmds.iter().map(|c| c.as_slice()).collect();
        let batched = a.apply_many(&refs);
        let sequential: Vec<Vec<u8>> = cmds.iter().map(|c| b.apply(c)).collect();
        assert_eq!(batched, sequential);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(batched[2], b"1");
    }

    #[test]
    fn query_matches_read_only_apply() {
        // kv: query(get) == apply(get); mutations through query are
        // refused.
        let mut kv = KvStore::new();
        kv.apply(&KvStore::enc_set(b"k", b"v1"));
        assert_eq!(kv.query(&KvStore::enc_get(b"k")), b"v1");
        assert_eq!(kv.query(&KvStore::enc_get(b"missing")), b"");
        assert_eq!(kv.query(&KvStore::enc_set(b"k", b"v2")), b"ERR");
        assert_eq!(kv.query(&KvStore::enc_get(b"k")), b"v1", "query must not mutate");

        // register: query returns the current value, without the
        // swap-and-return-previous of apply.
        let mut reg = Register::new();
        reg.apply(b"abc");
        assert_eq!(reg.query(b""), b"abc");
        assert_eq!(reg.query(b""), b"abc");

        // counter: query == a delta-0 apply.
        let mut c = Counter::new();
        c.apply(&7i64.to_le_bytes());
        assert_eq!(c.query(&[]), 7i64.to_le_bytes());
        assert_eq!(c.digest(), 7);

        // stateless default: empty.
        assert!(Noop.query(b"anything").is_empty());
    }

    #[test]
    fn snapshot_restore_roundtrips() {
        // Every stateful machine: restore(snapshot()) on a fresh machine
        // reproduces the digest and future behavior.
        let mut kv = KvStore::new();
        kv.apply(&KvStore::enc_set(b"k", b"v1"));
        kv.apply(&KvStore::enc_set(b"longer-key", b"longer-value"));
        let mut kv2 = KvStore::new();
        assert!(kv2.restore(&kv.snapshot()));
        assert_eq!(kv2.digest(), kv.digest());
        assert_eq!(kv2.apply(&KvStore::enc_get(b"k")), b"v1");

        let mut reg = Register::new();
        reg.apply(b"abc");
        let mut reg2 = Register::new();
        assert!(reg2.restore(&reg.snapshot()));
        assert_eq!(reg2.digest(), reg.digest());
        assert_eq!(reg2.apply(b"next"), b"abc");

        let mut c = Counter::new();
        c.apply(&7i64.to_le_bytes());
        let mut c2 = Counter::new();
        assert!(c2.restore(&c.snapshot()));
        assert_eq!(c2.digest(), c.digest());

        // Stateless default: only the empty snapshot restores.
        let mut n = Noop;
        assert!(n.snapshot().is_empty());
        assert!(n.restore(&[]));
        assert!(!n.restore(b"junk"));
    }

    #[test]
    fn malformed_snapshots_rejected() {
        let mut kv = KvStore::new();
        kv.apply(&KvStore::enc_set(b"k", b"v"));
        let before = kv.digest();
        assert!(!kv.restore(b"\xff\xff\xff\xff"));
        assert!(!kv.restore(&[1, 2, 3]));
        // A failed restore leaves prior state intact.
        assert_eq!(kv.digest(), before);
        let mut c = Counter::new();
        assert!(!c.restore(&[1, 2, 3]));
    }

    #[test]
    fn by_name_lookup() {
        for n in ["noop", "kv", "register", "counter"] {
            assert_eq!(by_name(n).unwrap().name(), n);
        }
        assert!(by_name("bogus").is_none());
    }
}
