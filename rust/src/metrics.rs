//! Latency/throughput series, computed the way the paper reports them.
//!
//! §8.1: "Throughput and latency are both computed using sliding one second
//! windows. Median latency is shown using solid lines, while the 95%
//! latency is shown as a shaded region." Tables 1 and 2 report the median,
//! interquartile range, and standard deviation of latency and throughput
//! over `[0,10) s` and `[10,20) s`. The §8.2 ablation (Figure 17) uses max
//! latency over 500 ms windows and throughput over 250 ms windows.

use crate::util::{stats, Stats};
use crate::{Time, SEC};

/// A client-side sample: `(completion_time, latency)` in ns.
pub type Sample = (Time, Time);

/// A timeline of windowed metrics (one row per stride step).
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    /// Window end, seconds.
    pub t: Vec<f64>,
    /// Median latency in the window, ms (NaN if empty).
    pub median_ms: Vec<f64>,
    /// 95th-percentile latency, ms.
    pub p95_ms: Vec<f64>,
    /// Max latency, ms.
    pub max_ms: Vec<f64>,
    /// Commands per second in the window.
    pub throughput: Vec<f64>,
}

impl Timeline {
    /// Render as aligned text columns (the harness's figure output).
    pub fn to_table(&self) -> String {
        let mut out = String::from("t_sec\tmedian_ms\tp95_ms\tmax_ms\tthroughput\n");
        for i in 0..self.t.len() {
            out.push_str(&format!(
                "{:.2}\t{:.3}\t{:.3}\t{:.3}\t{:.0}\n",
                self.t[i], self.median_ms[i], self.p95_ms[i], self.max_ms[i], self.throughput[i]
            ));
        }
        out
    }
}

/// Compute a sliding-window timeline over `samples` (must be sorted by
/// completion time; the harness sorts after merging clients).
pub fn timeline(samples: &[Sample], duration: Time, window: Time, stride: Time) -> Timeline {
    let mut tl = Timeline::default();
    if stride == 0 || window == 0 {
        return tl;
    }
    let mut t_end = window;
    while t_end <= duration {
        let t_start = t_end - window;
        // Binary search the sorted sample range.
        let lo = samples.partition_point(|(t, _)| *t < t_start);
        let hi = samples.partition_point(|(t, _)| *t < t_end);
        let lat_ms: Vec<f64> = samples[lo..hi]
            .iter()
            .map(|(_, l)| *l as f64 / 1e6)
            .collect();
        let s = stats(&lat_ms);
        tl.t.push(t_end as f64 / 1e9);
        tl.median_ms.push(s.map_or(f64::NAN, |s| s.median));
        tl.p95_ms.push(s.map_or(f64::NAN, |s| s.p95));
        tl.max_ms.push(s.map_or(f64::NAN, |s| s.max));
        tl.throughput
            .push((hi - lo) as f64 / (window as f64 / 1e9));
        t_end += stride;
    }
    tl
}

/// Summary for one table cell pair: latency stats (ms, per-request) and
/// throughput stats (cmds/s, over sliding 1-second windows at a 100 ms
/// stride) within `[from, to)` — the Table 1/2 methodology.
#[derive(Clone, Copy, Debug)]
pub struct IntervalSummary {
    pub latency: Stats,
    pub throughput: Stats,
}

/// Compute the Table-1-style summary of `samples` within `[from, to)`.
pub fn interval_summary(samples: &[Sample], from: Time, to: Time) -> Option<IntervalSummary> {
    let lo = samples.partition_point(|(t, _)| *t < from);
    let hi = samples.partition_point(|(t, _)| *t < to);
    let lat_ms: Vec<f64> = samples[lo..hi]
        .iter()
        .map(|(_, l)| *l as f64 / 1e6)
        .collect();
    let latency = stats(&lat_ms)?;

    // Throughput distribution over sliding windows inside the interval.
    let window = SEC;
    let stride = SEC / 10;
    let mut tputs: Vec<f64> = Vec::new();
    let mut t_end = from + window;
    while t_end <= to {
        let wlo = samples.partition_point(|(t, _)| *t < t_end - window);
        let whi = samples.partition_point(|(t, _)| *t < t_end);
        tputs.push((whi - wlo) as f64);
        t_end += stride;
    }
    let throughput = stats(&tputs)?;
    Some(IntervalSummary { latency, throughput })
}

/// Merge per-client sample vectors and sort by completion time.
pub fn merge_samples(per_client: Vec<Vec<Sample>>) -> Vec<Sample> {
    let mut all: Vec<Sample> = per_client.into_iter().flatten().collect();
    all.sort_unstable();
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::MS;

    fn mk_samples(n: u64, period: Time, latency: Time) -> Vec<Sample> {
        (1..=n).map(|i| (i * period, latency)).collect()
    }

    #[test]
    fn steady_stream_throughput() {
        // 1 command per ms for 5 s → 1000/s in every full window.
        let samples = mk_samples(5000, MS, 300_000);
        let tl = timeline(&samples, 5 * SEC, SEC, SEC);
        assert_eq!(tl.t.len(), 5);
        for tp in &tl.throughput {
            assert!((tp - 1000.0).abs() < 2.0, "tp={tp}");
        }
        for m in &tl.median_ms {
            assert!((m - 0.3).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_window_is_nan_zero() {
        let samples = vec![(3 * SEC + MS, MS)];
        let tl = timeline(&samples, 4 * SEC, SEC, SEC);
        assert!(tl.median_ms[0].is_nan());
        assert_eq!(tl.throughput[0], 0.0);
        assert_eq!(tl.throughput[3], 1.0);
    }

    #[test]
    fn interval_summary_basic() {
        let samples = mk_samples(20_000, MS / 2, 500_000); // 2000/s, 0.5ms
        let s = interval_summary(&samples, 0, 10 * SEC).unwrap();
        assert!((s.latency.median - 0.5).abs() < 1e-9);
        assert!((s.throughput.median - 2000.0).abs() < 5.0);
        assert!(s.throughput.stdev < 10.0);
    }

    #[test]
    fn interval_summary_empty() {
        assert!(interval_summary(&[], 0, SEC).is_none());
    }

    #[test]
    fn merge_sorts() {
        let merged = merge_samples(vec![vec![(5, 1), (10, 1)], vec![(3, 2), (7, 2)]]);
        let times: Vec<Time> = merged.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![3, 5, 7, 10]);
    }

    #[test]
    fn timeline_table_render() {
        let samples = mk_samples(10, MS, MS);
        let tl = timeline(&samples, SEC, SEC, SEC);
        let table = tl.to_table();
        assert!(table.starts_with("t_sec"));
        assert_eq!(table.lines().count(), 2);
    }
}
