//! Rounds (a.k.a. ballots).
//!
//! §3.4 of the paper (Optimization 2) constructs the set of rounds as
//! lexicographically ordered triples `(r, id, s)` where `r` ("epoch") and
//! `s` ("seq") are integers and `id` is a proposer id. A proposer owns every
//! round containing its id, and — crucially for Phase 1 Bypassing — the
//! proposer of `(r, id, s)` also owns the *next* round `(r, id, s+1)`.
//!
//! Leader changes bump the epoch `r`; in-leader reconfigurations bump the
//! sequence `s`.

use crate::NodeId;

/// A Paxos round `(epoch, proposer, seq)`, ordered lexicographically.
///
/// The paper's "round `-1`" (no round) is represented as `Option<Round>`
/// (`None`) throughout the codebase.
#[derive(
    Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug,
)]
pub struct Round {
    /// Leader-election epoch. Bumped when a new leader takes over.
    pub epoch: u64,
    /// The proposer that owns this round.
    pub proposer: NodeId,
    /// Reconfiguration sequence within an epoch. Bumped by the owning
    /// proposer to install a new configuration (§4.3).
    pub seq: u64,
}

impl Round {
    /// The first round owned by `proposer` in `epoch`.
    pub fn first(epoch: u64, proposer: NodeId) -> Round {
        Round {
            epoch,
            proposer,
            seq: 0,
        }
    }

    /// The next round owned by the *same* proposer (`s → s+1`). Phase 1
    /// Bypassing (Optimization 2) relies on this succession: there is no
    /// round between `self` and `self.next()`.
    pub fn next(&self) -> Round {
        Round {
            epoch: self.epoch,
            proposer: self.proposer,
            seq: self.seq + 1,
        }
    }

    /// The first round of the next epoch, owned by `proposer`. Used by a
    /// newly elected leader to guarantee its round exceeds every round of
    /// the previous leader regardless of how many reconfigurations (`seq`
    /// bumps) that leader performed.
    pub fn next_epoch(&self, proposer: NodeId) -> Round {
        Round {
            epoch: self.epoch + 1,
            proposer,
            seq: 0,
        }
    }

    /// True iff `next` is the immediate successor of `self` under the same
    /// owner — the precondition for Phase 1 Bypassing.
    pub fn is_immediate_successor(&self, next: &Round) -> bool {
        self.epoch == next.epoch && self.proposer == next.proposer && next.seq == self.seq + 1
    }
}

impl std::fmt::Display for Round {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{},{})", self.epoch, self.proposer, self.seq)
    }
}

/// Compare an `Option<Round>` ("-1 means none") with the paper's semantics:
/// `None < Some(r)` for every r.
pub fn opt_round_lt(a: Option<Round>, b: Option<Round>) -> bool {
    match (a, b) {
        (None, Some(_)) => true,
        (Some(x), Some(y)) => x < y,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexicographic_order() {
        // (0,a,0) < (0,a,1) < (0,b,0) < (1,a,0) for a < b — mirrors the
        // ordering table in §3.4.
        let a = 1;
        let b = 2;
        assert!(Round::first(0, a) < Round::first(0, a).next());
        assert!(Round::first(0, a).next() < Round::first(0, b));
        assert!(Round::first(0, b) < Round::first(1, a));
        assert!(Round { epoch: 0, proposer: a, seq: 99 } < Round::first(0, b));
    }

    #[test]
    fn successor_relation() {
        let r = Round::first(3, 7);
        assert!(r.is_immediate_successor(&r.next()));
        assert!(!r.is_immediate_successor(&r.next().next()));
        assert!(!r.is_immediate_successor(&r.next_epoch(7)));
        assert!(!r.is_immediate_successor(&r));
    }

    #[test]
    fn next_epoch_dominates_any_seq() {
        let r = Round { epoch: 5, proposer: 1, seq: 10_000 };
        assert!(r < r.next_epoch(0));
    }

    #[test]
    fn opt_round_ordering() {
        let r = Round::first(0, 1);
        assert!(opt_round_lt(None, Some(r)));
        assert!(!opt_round_lt(Some(r), None));
        assert!(!opt_round_lt(None, None));
        assert!(opt_round_lt(Some(r), Some(r.next())));
        assert!(!opt_round_lt(Some(r), Some(r)));
    }
}
