//! Binary wire codec.
//!
//! The TCP runtime frames [`Envelope`]s with this compact, hand-rolled
//! binary format (the build is fully self-contained; no serde). Every
//! protocol type implements [`Wire`]; `decode(encode(x)) == x` is checked
//! exhaustively by the tests and by the fuzz-ish property tests in
//! `rust/tests/`.
//!
//! Format conventions: fixed-width little-endian integers, `u32`-prefixed
//! lengths, one `u8` tag per enum variant. Decoding is panic-free: all
//! errors surface as `Err(CodecError)` (malformed input from the network
//! must never crash a node).

use crate::config::Configuration;
use crate::msg::{Command, Envelope, Msg, SlotVote, Value};
use crate::quorum::QuorumSpec;
use crate::round::Round;
use std::collections::{BTreeMap, BTreeSet};

/// Decoding error (malformed or truncated input).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecError(pub String);

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "codec error: {}", self.0)
    }
}
impl std::error::Error for CodecError {}

type R<T> = Result<T, CodecError>;

fn err<T>(msg: &str) -> R<T> {
    Err(CodecError(msg.to_string()))
}

/// Byte-buffer encoder.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::with_capacity(64) }
    }
    /// Clear the buffer, keeping its allocation. A long-lived `Enc` plus
    /// `reset()` turns per-message encode allocations into amortized
    /// ones — the TCP writer threads and anything else that serializes a
    /// stream of messages reuse one scratch buffer this way (see
    /// [`Wire::encode_into`]).
    pub fn reset(&mut self) {
        self.buf.clear();
    }
    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }
    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    pub fn bool(&mut self, x: bool) {
        self.u8(x as u8);
    }
    pub fn bytes(&mut self, x: &[u8]) {
        self.u32(x.len() as u32);
        self.buf.extend_from_slice(x);
    }
    pub fn str(&mut self, x: &str) {
        self.bytes(x.as_bytes());
    }
}

/// Byte-buffer decoder.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> R<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return err("truncated");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    pub fn u8(&mut self) -> R<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> R<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> R<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn bool(&mut self) -> R<bool> {
        Ok(self.u8()? != 0)
    }
    pub fn bytes(&mut self) -> R<Vec<u8>> {
        let n = self.u32()? as usize;
        if n > 64 << 20 {
            return err("length too large");
        }
        Ok(self.take(n)?.to_vec())
    }
    pub fn str(&mut self) -> R<String> {
        String::from_utf8(self.bytes()?).map_err(|_| CodecError("invalid utf8".into()))
    }
    /// True when the whole buffer was consumed.
    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Wire-serializable type.
pub trait Wire: Sized {
    fn enc(&self, e: &mut Enc);
    fn dec(d: &mut Dec) -> R<Self>;

    fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        self.enc(&mut e);
        e.buf
    }
    /// Encode into a reused scratch buffer (reset first): the result is
    /// `scratch.buf`. The amortized-allocation counterpart of
    /// [`Wire::encode`] for anything that serializes a message stream.
    /// (The TCP writer needs a length prefix *before* the body, so it
    /// uses its own framing variant, [`crate::net::encode_frame_into`],
    /// built on the same [`Enc::reset`] idiom.)
    fn encode_into(&self, scratch: &mut Enc) {
        scratch.reset();
        self.enc(scratch);
    }
    fn decode(buf: &[u8]) -> R<Self> {
        let mut d = Dec::new(buf);
        let v = Self::dec(&mut d)?;
        if !d.done() {
            return err("trailing bytes");
        }
        Ok(v)
    }
}

// ---- Primitive / container impls ----

impl Wire for u64 {
    fn enc(&self, e: &mut Enc) {
        e.u64(*self)
    }
    fn dec(d: &mut Dec) -> R<Self> {
        d.u64()
    }
}

impl Wire for u32 {
    fn enc(&self, e: &mut Enc) {
        e.u32(*self)
    }
    fn dec(d: &mut Dec) -> R<Self> {
        d.u32()
    }
}

impl Wire for usize {
    fn enc(&self, e: &mut Enc) {
        e.u64(*self as u64)
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(d.u64()? as usize)
    }
}

impl<T: Wire> Wire for Option<T> {
    fn enc(&self, e: &mut Enc) {
        match self {
            None => e.u8(0),
            Some(x) => {
                e.u8(1);
                x.enc(e);
            }
        }
    }
    fn dec(d: &mut Dec) -> R<Self> {
        match d.u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::dec(d)?)),
            _ => err("bad Option tag"),
        }
    }
}

impl<T: Wire> Wire for Vec<T> {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.len() as u32);
        for x in self {
            x.enc(e);
        }
    }
    fn dec(d: &mut Dec) -> R<Self> {
        let n = d.u32()? as usize;
        if n > 16 << 20 {
            return err("vec too large");
        }
        let mut v = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            v.push(T::dec(d)?);
        }
        Ok(v)
    }
}

impl<K: Wire + Ord, V: Wire> Wire for BTreeMap<K, V> {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.len() as u32);
        for (k, v) in self {
            k.enc(e);
            v.enc(e);
        }
    }
    fn dec(d: &mut Dec) -> R<Self> {
        let n = d.u32()? as usize;
        let mut m = BTreeMap::new();
        for _ in 0..n {
            let k = K::dec(d)?;
            let v = V::dec(d)?;
            m.insert(k, v);
        }
        Ok(m)
    }
}

impl<T: Wire + Ord> Wire for BTreeSet<T> {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.len() as u32);
        for x in self {
            x.enc(e);
        }
    }
    fn dec(d: &mut Dec) -> R<Self> {
        let n = d.u32()? as usize;
        let mut s = BTreeSet::new();
        for _ in 0..n {
            s.insert(T::dec(d)?);
        }
        Ok(s)
    }
}

impl<A: Wire, B: Wire> Wire for (A, B) {
    fn enc(&self, e: &mut Enc) {
        self.0.enc(e);
        self.1.enc(e);
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok((A::dec(d)?, B::dec(d)?))
    }
}

// ---- Protocol types ----

impl Wire for Round {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.epoch);
        e.u32(self.proposer);
        e.u64(self.seq);
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(Round { epoch: d.u64()?, proposer: d.u32()?, seq: d.u64()? })
    }
}

impl Wire for QuorumSpec {
    fn enc(&self, e: &mut Enc) {
        match self {
            QuorumSpec::Majority => e.u8(0),
            QuorumSpec::Flexible { p1, p2 } => {
                e.u8(1);
                p1.enc(e);
                p2.enc(e);
            }
            QuorumSpec::FastUnanimous => e.u8(2),
            QuorumSpec::Explicit { p1, p2 } => {
                e.u8(3);
                p1.enc(e);
                p2.enc(e);
            }
        }
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(match d.u8()? {
            0 => QuorumSpec::Majority,
            1 => QuorumSpec::Flexible { p1: Wire::dec(d)?, p2: Wire::dec(d)? },
            2 => QuorumSpec::FastUnanimous,
            3 => QuorumSpec::Explicit { p1: Wire::dec(d)?, p2: Wire::dec(d)? },
            _ => return err("bad QuorumSpec tag"),
        })
    }
}

impl Wire for Configuration {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.id);
        self.acceptors.enc(e);
        self.quorum.enc(e);
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(Configuration { id: d.u64()?, acceptors: Wire::dec(d)?, quorum: Wire::dec(d)? })
    }
}

impl Wire for Command {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.client);
        e.u64(self.seq);
        e.bytes(&self.payload);
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(Command { client: d.u32()?, seq: d.u64()?, payload: d.bytes()? })
    }
}

impl Wire for Value {
    fn enc(&self, e: &mut Enc) {
        match self {
            Value::Cmd(c) => {
                e.u8(0);
                c.enc(e);
            }
            Value::Noop => e.u8(1),
            Value::Reconfig(c) => {
                e.u8(2);
                c.enc(e);
            }
            Value::Batch(cmds) => {
                e.u8(3);
                cmds.enc(e);
            }
        }
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(match d.u8()? {
            0 => Value::Cmd(Command::dec(d)?),
            1 => Value::Noop,
            2 => Value::Reconfig(Configuration::dec(d)?),
            3 => Value::Batch(Wire::dec(d)?),
            _ => return err("bad Value tag"),
        })
    }
}

impl Wire for SlotVote {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.slot);
        self.vr.enc(e);
        self.vv.enc(e);
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(SlotVote { slot: d.u64()?, vr: Round::dec(d)?, vv: Value::dec(d)? })
    }
}

impl Wire for Msg {
    fn enc(&self, e: &mut Enc) {
        use Msg::*;
        match self {
            MatchA { group, round, config } => {
                e.u8(0);
                e.u32(*group);
                round.enc(e);
                config.enc(e);
            }
            MatchB { group, round, gc_watermark, prior } => {
                e.u8(1);
                e.u32(*group);
                round.enc(e);
                gc_watermark.enc(e);
                prior.enc(e);
            }
            MatchNack { group, round, blocking } => {
                e.u8(2);
                e.u32(*group);
                round.enc(e);
                blocking.enc(e);
            }
            Phase1A { round, from_slot } => {
                e.u8(3);
                round.enc(e);
                e.u64(*from_slot);
            }
            Phase1B { round, votes, chosen_watermark } => {
                e.u8(4);
                round.enc(e);
                votes.enc(e);
                e.u64(*chosen_watermark);
            }
            Phase2A { round, slot, value } => {
                e.u8(5);
                round.enc(e);
                e.u64(*slot);
                value.enc(e);
            }
            Phase2B { round, slot } => {
                e.u8(6);
                round.enc(e);
                e.u64(*slot);
            }
            Nack { round, higher } => {
                e.u8(7);
                round.enc(e);
                higher.enc(e);
            }
            Chosen { slot, value } => {
                e.u8(8);
                e.u64(*slot);
                value.enc(e);
            }
            ReplicaAck { upto } => {
                e.u8(9);
                e.u64(*upto);
            }
            PrefixPersisted { round, upto } => {
                e.u8(10);
                round.enc(e);
                e.u64(*upto);
            }
            PrefixAck { round, upto } => {
                e.u8(11);
                round.enc(e);
                e.u64(*upto);
            }
            ReadPrefix { from } => {
                e.u8(12);
                e.u64(*from);
            }
            PrefixResp { entries, upto } => {
                e.u8(13);
                entries.enc(e);
                e.u64(*upto);
            }
            GarbageA { group, round } => {
                e.u8(14);
                e.u32(*group);
                round.enc(e);
            }
            GarbageB { group, round } => {
                e.u8(15);
                e.u32(*group);
                round.enc(e);
            }
            ClientRequest { group, cmd, lowest } => {
                e.u8(16);
                e.u32(*group);
                cmd.enc(e);
                e.u64(*lowest);
            }
            ClientReply { group, seq, result } => {
                e.u8(17);
                e.u32(*group);
                e.u64(*seq);
                e.bytes(result);
            }
            NotLeader { group, hint } => {
                e.u8(18);
                e.u32(*group);
                hint.enc(e);
            }
            StopA => e.u8(19),
            StopB { log, gc_watermarks } => {
                e.u8(20);
                log.enc(e);
                gc_watermarks.enc(e);
            }
            Bootstrap { log, gc_watermarks, generation } => {
                e.u8(21);
                log.enc(e);
                gc_watermarks.enc(e);
                e.u64(*generation);
            }
            BootstrapAck => e.u8(22),
            MatchmakersActivated { generation, matchmakers } => {
                e.u8(23);
                e.u64(*generation);
                matchmakers.enc(e);
            }
            MetaPhase1A { round, generation } => {
                e.u8(24);
                round.enc(e);
                e.u64(*generation);
            }
            MetaPhase1B { round, vr, vv } => {
                e.u8(25);
                round.enc(e);
                vr.enc(e);
                vv.enc(e);
            }
            MetaPhase2A { round, generation, matchmakers } => {
                e.u8(26);
                round.enc(e);
                e.u64(*generation);
                matchmakers.enc(e);
            }
            MetaPhase2B { round } => {
                e.u8(27);
                round.enc(e);
            }
            Heartbeat { epoch } => {
                e.u8(28);
                e.u64(*epoch);
            }
            HeartbeatReply { epoch } => {
                e.u8(29);
                e.u64(*epoch);
            }
            FastPropose { round, value } => {
                e.u8(30);
                round.enc(e);
                value.enc(e);
            }
            FastPhase2B { round, value } => {
                e.u8(31);
                round.enc(e);
                value.enc(e);
            }
            CatchUp { below, peer } => {
                e.u8(32);
                e.u64(*below);
                e.u32(*peer);
            }
            SnapshotRequest { from } => {
                e.u8(33);
                e.u64(*from);
            }
            SnapshotResp { base, state, entries } => {
                e.u8(34);
                e.u64(*base);
                e.bytes(state);
                entries.enc(e);
            }
            Read { group, seq, payload } => {
                e.u8(35);
                e.u32(*group);
                e.u64(*seq);
                e.bytes(payload);
            }
            ReadReply { group, seq, result } => {
                e.u8(36);
                e.u32(*group);
                e.u64(*seq);
                e.bytes(result);
            }
            ReadIndexReq { id } => {
                e.u8(37);
                e.u64(*id);
            }
            ReadIndexResp { id, upto } => {
                e.u8(38);
                e.u64(*id);
                e.u64(*upto);
            }
            NotLeaseholder { group, hint } => {
                e.u8(39);
                e.u32(*group);
                hint.enc(e);
            }
            LeaseRenew { round, seq } => {
                e.u8(40);
                round.enc(e);
                e.u64(*seq);
            }
            LeaseRenewAck { round, seq } => {
                e.u8(41);
                round.enc(e);
                e.u64(*seq);
            }
            LeaseGrant { round, upto, granted_at, valid_until } => {
                e.u8(42);
                round.enc(e);
                e.u64(*upto);
                e.u64(*granted_at);
                e.u64(*valid_until);
            }
            SnapshotChunk { base, seq, total, bytes } => {
                e.u8(43);
                e.u64(*base);
                e.u32(*seq);
                e.u32(*total);
                e.bytes(bytes);
            }
            SnapshotResume { base, next } => {
                e.u8(44);
                e.u64(*base);
                e.u32(*next);
            }
            Busy { group, seq, retry_after_us } => {
                e.u8(45);
                e.u32(*group);
                e.u64(*seq);
                e.u64(*retry_after_us);
            }
        }
    }

    fn dec(d: &mut Dec) -> R<Self> {
        use Msg::*;
        Ok(match d.u8()? {
            0 => MatchA {
                group: d.u32()?,
                round: Round::dec(d)?,
                config: Configuration::dec(d)?,
            },
            1 => MatchB {
                group: d.u32()?,
                round: Round::dec(d)?,
                gc_watermark: Wire::dec(d)?,
                prior: Wire::dec(d)?,
            },
            2 => MatchNack {
                group: d.u32()?,
                round: Round::dec(d)?,
                blocking: Round::dec(d)?,
            },
            3 => Phase1A { round: Round::dec(d)?, from_slot: d.u64()? },
            4 => Phase1B {
                round: Round::dec(d)?,
                votes: Wire::dec(d)?,
                chosen_watermark: d.u64()?,
            },
            5 => Phase2A { round: Round::dec(d)?, slot: d.u64()?, value: Value::dec(d)? },
            6 => Phase2B { round: Round::dec(d)?, slot: d.u64()? },
            7 => Nack { round: Round::dec(d)?, higher: Round::dec(d)? },
            8 => Chosen { slot: d.u64()?, value: Value::dec(d)? },
            9 => ReplicaAck { upto: d.u64()? },
            10 => PrefixPersisted { round: Round::dec(d)?, upto: d.u64()? },
            11 => PrefixAck { round: Round::dec(d)?, upto: d.u64()? },
            12 => ReadPrefix { from: d.u64()? },
            13 => PrefixResp { entries: Wire::dec(d)?, upto: d.u64()? },
            14 => GarbageA { group: d.u32()?, round: Round::dec(d)? },
            15 => GarbageB { group: d.u32()?, round: Round::dec(d)? },
            16 => ClientRequest { group: d.u32()?, cmd: Command::dec(d)?, lowest: d.u64()? },
            17 => ClientReply { group: d.u32()?, seq: d.u64()?, result: d.bytes()? },
            18 => NotLeader { group: d.u32()?, hint: Wire::dec(d)? },
            19 => StopA,
            20 => StopB { log: Wire::dec(d)?, gc_watermarks: Wire::dec(d)? },
            21 => Bootstrap {
                log: Wire::dec(d)?,
                gc_watermarks: Wire::dec(d)?,
                generation: d.u64()?,
            },
            22 => BootstrapAck,
            23 => MatchmakersActivated { generation: d.u64()?, matchmakers: Wire::dec(d)? },
            24 => MetaPhase1A { round: Round::dec(d)?, generation: d.u64()? },
            25 => MetaPhase1B { round: Round::dec(d)?, vr: Wire::dec(d)?, vv: Wire::dec(d)? },
            26 => MetaPhase2A { round: Round::dec(d)?, generation: d.u64()?, matchmakers: Wire::dec(d)? },
            27 => MetaPhase2B { round: Round::dec(d)? },
            28 => Heartbeat { epoch: d.u64()? },
            29 => HeartbeatReply { epoch: d.u64()? },
            30 => FastPropose { round: Round::dec(d)?, value: Value::dec(d)? },
            31 => FastPhase2B { round: Round::dec(d)?, value: Value::dec(d)? },
            32 => CatchUp { below: d.u64()?, peer: d.u32()? },
            33 => SnapshotRequest { from: d.u64()? },
            34 => SnapshotResp { base: d.u64()?, state: d.bytes()?, entries: Wire::dec(d)? },
            35 => Read { group: d.u32()?, seq: d.u64()?, payload: d.bytes()? },
            36 => ReadReply { group: d.u32()?, seq: d.u64()?, result: d.bytes()? },
            37 => ReadIndexReq { id: d.u64()? },
            38 => ReadIndexResp { id: d.u64()?, upto: d.u64()? },
            39 => NotLeaseholder { group: d.u32()?, hint: Wire::dec(d)? },
            40 => LeaseRenew { round: Round::dec(d)?, seq: d.u64()? },
            41 => LeaseRenewAck { round: Round::dec(d)?, seq: d.u64()? },
            42 => LeaseGrant {
                round: Round::dec(d)?,
                upto: d.u64()?,
                granted_at: d.u64()?,
                valid_until: d.u64()?,
            },
            43 => SnapshotChunk {
                base: d.u64()?,
                seq: d.u32()?,
                total: d.u32()?,
                bytes: d.bytes()?,
            },
            44 => SnapshotResume { base: d.u64()?, next: d.u32()? },
            45 => Busy { group: d.u32()?, seq: d.u64()?, retry_after_us: d.u64()? },
            t => return err(&format!("bad Msg tag {t}")),
        })
    }
}

impl Wire for Envelope {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.from);
        e.u32(self.to);
        self.msg.enc(e);
    }
    fn dec(d: &mut Dec) -> R<Self> {
        Ok(Envelope { from: d.u32()?, to: d.u32()?, msg: Msg::dec(d)? })
    }
}

/// A representative sample of every message variant, used by roundtrip
/// tests here and in the integration suite.
pub fn sample_messages() -> Vec<Msg> {
    use Msg::*;
    let r0 = Round { epoch: 0, proposer: 1, seq: 0 };
    let r1 = Round { epoch: 1, proposer: 2, seq: 3 };
    let cfg = Configuration::majority(7, vec![4, 5, 6]);
    let cmd = Command { client: 9, seq: 42, payload: vec![1, 2, 3] };
    let mut log = BTreeMap::new();
    log.insert(r0, cfg.clone());
    log.insert(r1, Configuration {
        id: 8,
        acceptors: vec![10, 11, 12, 13],
        quorum: QuorumSpec::Explicit {
            p1: vec![[0usize, 1].into_iter().collect()],
            p2: vec![[2usize, 3].into_iter().collect()],
        },
    });
    // Multi-group matchmaker state: group 0 busy, group 5 with one entry.
    let mut mm_log = BTreeMap::new();
    mm_log.insert(0u32, log.clone());
    mm_log.insert(5u32, [(r0, cfg.clone())].into_iter().collect());
    let mut gc_wms = BTreeMap::new();
    gc_wms.insert(0u32, r0);
    vec![
        MatchA { group: 1, round: r0, config: cfg.clone() },
        MatchB { group: 1, round: r1, gc_watermark: Some(r0), prior: log.clone() },
        MatchNack { group: 2, round: r0, blocking: r1 },
        Phase1A { round: r1, from_slot: 17 },
        Phase1B {
            round: r1,
            votes: vec![SlotVote { slot: 3, vr: r0, vv: Value::Cmd(cmd.clone()) }],
            chosen_watermark: 2,
        },
        Phase2A {
            round: r1,
            slot: 5,
            value: Value::Batch(vec![
                cmd.clone(),
                Command { client: 10, seq: 43, payload: vec![4, 5] },
            ]),
        },
        Phase2B { round: r1, slot: 5 },
        Nack { round: r0, higher: r1 },
        Chosen { slot: 6, value: Value::Reconfig(cfg.clone()) },
        ReplicaAck { upto: 10 },
        PrefixPersisted { round: r1, upto: 4 },
        PrefixAck { round: r1, upto: 4 },
        ReadPrefix { from: 0 },
        PrefixResp { entries: vec![(0, Value::Noop)], upto: 1 },
        GarbageA { group: 3, round: r1 },
        GarbageB { group: 3, round: r1 },
        ClientRequest { group: 1, cmd: cmd.clone(), lowest: 42 },
        ClientReply { group: 1, seq: 42, result: vec![9, 9] },
        NotLeader { group: 2, hint: Some(3) },
        StopA,
        StopB { log: mm_log.clone(), gc_watermarks: BTreeMap::new() },
        Bootstrap { log: mm_log, gc_watermarks: gc_wms, generation: 3 },
        BootstrapAck,
        MatchmakersActivated { generation: 4, matchmakers: vec![1, 2, 3] },
        MetaPhase1A { round: r0, generation: 2 },
        MetaPhase1B { round: r0, vr: Some(r1), vv: Some(vec![7, 8]) },
        MetaPhase2A { round: r0, generation: 2, matchmakers: vec![7, 8, 9] },
        MetaPhase2B { round: r0 },
        Heartbeat { epoch: 2 },
        HeartbeatReply { epoch: 2 },
        FastPropose { round: r1, value: Value::Cmd(cmd.clone()) },
        FastPhase2B { round: r1, value: Value::Noop },
        CatchUp { below: 4096, peer: 12 },
        SnapshotRequest { from: 17 },
        SnapshotResp {
            base: 4096,
            state: vec![0xde, 0xad, 0xbe, 0xef],
            entries: vec![(4096, Value::Cmd(cmd)), (4097, Value::Noop)],
        },
        Read { group: 1, seq: 7, payload: vec![b'g', 1, b'k'] },
        ReadReply { group: 1, seq: 7, result: vec![1, 2, 3] },
        ReadIndexReq { id: 5 },
        ReadIndexResp { id: 5, upto: 4097 },
        NotLeaseholder { group: 2, hint: Some(15) },
        LeaseRenew { round: r1, seq: 12 },
        LeaseRenewAck { round: r1, seq: 12 },
        LeaseGrant { round: r1, upto: 4098, granted_at: 77_000, valid_until: 50_077_000 },
        SnapshotChunk { base: 4096, seq: 1, total: 3, bytes: vec![0xca, 0xfe] },
        SnapshotResume { base: 4096, next: 2 },
        Busy { group: 1, seq: 42, retry_after_us: 2_500 },
    ]
}

/// The wire-tag registry: every [`Msg`] variant paired with the codec
/// tag its `enc` arm writes, in tag order. This table is the *auditable*
/// statement of the wire format; [`check_tag_table`] (run by the test
/// suite) enforces that it is gap-free and duplicate-free over
/// `0..len`, and the codec tests cross-check it against the actual
/// encoder output and [`Msg::variant_name`] — so adding a variant
/// without registering a tag here, reusing a tag, or leaving a hole in
/// the tag space all fail the build.
pub const MSG_TAG_TABLE: &[(u8, &str)] = &[
    (0, "MatchA"),
    (1, "MatchB"),
    (2, "MatchNack"),
    (3, "Phase1A"),
    (4, "Phase1B"),
    (5, "Phase2A"),
    (6, "Phase2B"),
    (7, "Nack"),
    (8, "Chosen"),
    (9, "ReplicaAck"),
    (10, "PrefixPersisted"),
    (11, "PrefixAck"),
    (12, "ReadPrefix"),
    (13, "PrefixResp"),
    (14, "GarbageA"),
    (15, "GarbageB"),
    (16, "ClientRequest"),
    (17, "ClientReply"),
    (18, "NotLeader"),
    (19, "StopA"),
    (20, "StopB"),
    (21, "Bootstrap"),
    (22, "BootstrapAck"),
    (23, "MatchmakersActivated"),
    (24, "MetaPhase1A"),
    (25, "MetaPhase1B"),
    (26, "MetaPhase2A"),
    (27, "MetaPhase2B"),
    (28, "Heartbeat"),
    (29, "HeartbeatReply"),
    (30, "FastPropose"),
    (31, "FastPhase2B"),
    (32, "CatchUp"),
    (33, "SnapshotRequest"),
    (34, "SnapshotResp"),
    (35, "Read"),
    (36, "ReadReply"),
    (37, "ReadIndexReq"),
    (38, "ReadIndexResp"),
    (39, "NotLeaseholder"),
    (40, "LeaseRenew"),
    (41, "LeaseRenewAck"),
    (42, "LeaseGrant"),
    (43, "SnapshotChunk"),
    (44, "SnapshotResume"),
    (45, "Busy"),
];

/// Validate a tag table: tags must be exactly `0..table.len()` with no
/// duplicate tags and no duplicate variant names. Panics with a
/// descriptive message on the first defect (tests feed it doctored
/// tables to prove each failure mode fires).
pub fn check_tag_table(table: &[(u8, &str)]) {
    let mut names = BTreeSet::new();
    for &(_, name) in table {
        if !names.insert(name) {
            panic!("duplicate variant {name} in tag table");
        }
    }
    let span = table.iter().map(|&(t, _)| t as usize + 1).max().unwrap_or(0);
    let mut seen: Vec<Option<&str>> = vec![None; span];
    for &(tag, name) in table {
        if let Some(prev) = seen[tag as usize] {
            panic!("duplicate tag {tag} ({prev} and {name})");
        }
        seen[tag as usize] = Some(name);
    }
    if let Some(gap) = seen.iter().position(|s| s.is_none()) {
        panic!("gap in tag table: no entry for tag {gap}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_roundtrips() {
        for m in sample_messages() {
            let env = Envelope { from: 3, to: 9, msg: m.clone() };
            let bytes = env.encode();
            let back = Envelope::decode(&bytes).unwrap_or_else(|e| panic!("{m:?}: {e}"));
            assert_eq!(back.msg, m);
            assert_eq!((back.from, back.to), (3, 9));
        }
    }

    #[test]
    fn sample_covers_all_tags() {
        // 46 variants, tags 0..=45: decoding tag 46 must fail.
        assert_eq!(sample_messages().len(), 46);
        let mut e = Enc::new();
        e.u8(46);
        assert!(Msg::decode(&e.buf).is_err());
    }

    #[test]
    fn tag_table_is_gap_free_and_duplicate_free() {
        check_tag_table(MSG_TAG_TABLE);
    }

    #[test]
    fn tag_table_matches_encoder_and_variant_names() {
        // Exactly-one mapping, cross-checked three ways against the real
        // encoder: (1) every sample message's first encoded byte is the
        // table tag registered for its variant name; (2) every variant
        // name in the table is exercised by the sample set (with
        // `sample_covers_all_tags` pinning the sample count to the
        // variant count, this makes the table total over Msg); (3)
        // re-decoding preserves the variant name.
        let by_name: BTreeMap<&str, u8> =
            MSG_TAG_TABLE.iter().map(|&(t, n)| (n, t)).collect();
        let mut seen = BTreeSet::new();
        for m in sample_messages() {
            let name = m.variant_name();
            let tag = *by_name
                .get(name)
                .unwrap_or_else(|| panic!("variant {name} missing from MSG_TAG_TABLE"));
            let bytes = m.encode();
            assert_eq!(bytes[0], tag, "{name}: encoder wrote tag {}, table says {tag}", bytes[0]);
            assert_eq!(Msg::decode(&bytes).unwrap().variant_name(), name);
            seen.insert(name);
        }
        for &(_, name) in MSG_TAG_TABLE {
            assert!(seen.contains(name), "table entry {name} not covered by sample_messages");
        }
        assert_eq!(seen.len(), MSG_TAG_TABLE.len());
    }

    #[test]
    #[should_panic(expected = "duplicate tag 1")]
    fn tag_table_lint_catches_duplicate_tags() {
        check_tag_table(&[(0, "MatchA"), (1, "MatchB"), (1, "MatchNack")]);
    }

    #[test]
    #[should_panic(expected = "duplicate variant MatchA")]
    fn tag_table_lint_catches_duplicate_names() {
        check_tag_table(&[(0, "MatchA"), (1, "MatchA")]);
    }

    #[test]
    #[should_panic(expected = "no entry for tag 1")]
    fn tag_table_lint_catches_gaps() {
        check_tag_table(&[(0, "MatchA"), (2, "MatchB")]);
    }

    #[test]
    fn encode_into_scratch_matches_encode() {
        // The scratch-buffer path is byte-identical to the allocating
        // path, and reusing the scratch across messages never leaks
        // bytes from the previous message.
        let mut scratch = Enc::new();
        for m in sample_messages() {
            let env = Envelope { from: 3, to: 9, msg: m };
            env.encode_into(&mut scratch);
            assert_eq!(scratch.buf, env.encode());
        }
    }

    #[test]
    fn truncated_input_is_error_not_panic() {
        for m in sample_messages() {
            let bytes = m.encode();
            for cut in 0..bytes.len() {
                let _ = Msg::decode(&bytes[..cut]); // must not panic
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = Msg::StopA.encode();
        bytes.push(0);
        assert!(Msg::decode(&bytes).is_err());
    }

    #[test]
    fn garbage_is_error_not_panic() {
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..2000 {
            let n = rng.gen_range(64) as usize;
            let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let _ = Envelope::decode(&bytes); // must not panic
        }
    }

    #[test]
    fn containers_roundtrip() {
        let v: Vec<u64> = vec![1, 2, 3];
        assert_eq!(Vec::<u64>::decode(&v.encode()).unwrap(), v);
        let o: Option<u32> = None;
        assert_eq!(Option::<u32>::decode(&o.encode()).unwrap(), o);
        let mut m = BTreeMap::new();
        m.insert(1u64, 2u64);
        assert_eq!(BTreeMap::<u64, u64>::decode(&m.encode()).unwrap(), m);
    }
}
