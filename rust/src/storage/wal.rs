//! [`WalStorage`]: the on-disk [`Storage`] — fsync'd, CRC-framed,
//! length-prefixed segment files with rotation, watermark-driven
//! compaction, and full/delta snapshot files.
//!
//! Layout of a data directory (one per role instance, e.g.
//! `<data-dir>/acceptor-10/`):
//!
//! ```text
//! wal-00000000.log     record segments, rotated at `segment_bytes`
//! wal-00000001.log     (replayed in sequence order on restart)
//! ...
//! snap-<base>.full     latest full snapshot (slots < base applied)
//! snap-<base>.delta    byte-delta against the latest full snapshot
//! ```
//!
//! Crash semantics: a record is appended as `[len][crc][body]` and
//! fsync'd before [`WalStorage::append`] returns, so a `kill -9` can
//! only ever leave a *torn tail* — a partial frame at the end of the
//! newest segment. Replay verifies each frame's CRC and stops at the
//! first bad one, truncating the file there; everything acked before the
//! crash survives by construction. Snapshots are written to a temp file,
//! fsync'd, then renamed into place, so a crash mid-snapshot leaves the
//! previous snapshot intact.

use super::{apply_delta, crc32, encode_delta, Storage, StorageError, WalRecord, MAX_RECORD};
use crate::codec::{Enc, Wire};
use crate::Slot;
use std::fs::{self, File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// Tuning knobs for [`WalStorage`].
#[derive(Clone, Copy, Debug)]
pub struct WalOptions {
    /// fsync each append before acking (the safe default). Turning this
    /// off trades crash safety for throughput — benchmarks only.
    pub fsync: bool,
    /// Rotate to a fresh segment once the current one exceeds this.
    pub segment_bytes: u64,
    /// Write a full snapshot every `full_every` snapshots; the ones in
    /// between are stored as byte-deltas against the last full.
    pub full_every: u32,
}

impl Default for WalOptions {
    fn default() -> WalOptions {
        WalOptions { fsync: true, segment_bytes: 4 << 20, full_every: 4 }
    }
}

/// Process-wide fsync-stall injection (nanoseconds of extra latency per
/// fsync'd append), the nemesis `stall(node,µs)` fault on the TCP
/// runtime: a disk that still completes every write, just slowly — the
/// gray failure that stalls a quorum member without tripping crash
/// detection. Zero (the default) is a no-op on the hot path beyond one
/// relaxed atomic load. Set via [`set_fsync_stall_us`] from the
/// [`crate::net::FaultShim`] schedule thread.
static FSYNC_STALL_NS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Arm (or with `0`, disarm) the process-wide fsync stall.
pub fn set_fsync_stall_us(stall_us: u64) {
    FSYNC_STALL_NS.store(
        stall_us.saturating_mul(1000),
        std::sync::atomic::Ordering::Relaxed,
    );
}

/// The currently armed fsync stall, in microseconds.
pub fn fsync_stall_us() -> u64 {
    FSYNC_STALL_NS.load(std::sync::atomic::Ordering::Relaxed) / 1000
}

/// The on-disk write-ahead log. See the module docs for the format.
pub struct WalStorage {
    dir: PathBuf,
    opts: WalOptions,
    /// Sequence number of the open (newest) segment.
    seg_seq: u64,
    /// The open segment, in append mode.
    seg: File,
    /// Bytes currently in the open segment.
    seg_len: u64,
    /// Scratch encoder reused across appends.
    scratch: Enc,
    /// Last *full* snapshot bytes (delta base), loaded lazily.
    last_full: Option<(Slot, Vec<u8>)>,
    /// Snapshots written since the last full one.
    since_full: u32,
}

impl std::fmt::Debug for WalStorage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WalStorage")
            .field("dir", &self.dir)
            .field("seg_seq", &self.seg_seq)
            .field("seg_len", &self.seg_len)
            .finish_non_exhaustive()
    }
}

fn seg_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:08}.log"))
}

impl WalStorage {
    /// Open (or create) the WAL in `dir`.
    pub fn open(dir: impl Into<PathBuf>, opts: WalOptions) -> Result<WalStorage, StorageError> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let seg_seq = Self::segments(&dir)?.last().copied().unwrap_or(0);
        let path = seg_path(&dir, seg_seq);
        let seg = OpenOptions::new().create(true).append(true).open(&path)?;
        let seg_len = seg.metadata()?.len();
        Ok(WalStorage {
            dir,
            opts,
            seg_seq,
            seg,
            seg_len,
            scratch: Enc::new(),
            last_full: None,
            since_full: 0,
        })
    }

    /// Existing segment sequence numbers, ascending.
    fn segments(dir: &Path) -> Result<Vec<u64>, StorageError> {
        let mut seqs = Vec::new();
        for entry in fs::read_dir(dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(seq) = name
                .strip_prefix("wal-")
                .and_then(|s| s.strip_suffix(".log"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                seqs.push(seq);
            }
        }
        seqs.sort_unstable();
        Ok(seqs)
    }

    /// fsync the directory itself so renames/creates/removes are durable.
    fn sync_dir(&self) -> Result<(), StorageError> {
        if self.opts.fsync {
            File::open(&self.dir)?.sync_all()?;
        }
        Ok(())
    }

    fn rotate(&mut self) -> Result<(), StorageError> {
        self.seg.sync_all()?;
        self.seg_seq += 1;
        let path = seg_path(&self.dir, self.seg_seq);
        self.seg = OpenOptions::new().create(true).append(true).open(path)?;
        self.seg_len = 0;
        self.sync_dir()
    }

    /// Parse the frames of one segment's bytes. Returns the decoded
    /// records and the byte offset of the first invalid frame (== len
    /// when the whole segment is valid).
    fn scan(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
        let mut recs = Vec::new();
        let mut pos = 0usize;
        loop {
            let Some(header) = bytes.get(pos..pos + 8) else {
                return (recs, pos); // clean EOF or torn header
            };
            let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
            if len > MAX_RECORD {
                return (recs, pos); // corrupt length
            }
            let Some(body) = bytes.get(pos + 8..pos + 8 + len) else {
                return (recs, pos); // torn body
            };
            if crc32(body) != crc {
                return (recs, pos); // bit flip / torn write
            }
            let Ok(rec) = WalRecord::decode(body) else {
                return (recs, pos); // CRC-valid but undecodable: corrupt
            };
            recs.push(rec);
            pos += 8 + len;
        }
    }

    /// Number of record segments on disk (tests).
    pub fn segment_count(&self) -> Result<usize, StorageError> {
        Ok(Self::segments(&self.dir)?.len())
    }

    /// Every snapshot file on disk: `(base, is_full, path)`.
    fn all_snapshot_files(&self) -> Result<Vec<(Slot, bool, PathBuf)>, StorageError> {
        let mut out = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(rest) = name.strip_prefix("snap-") else { continue };
            let parse = |s: &str| s.parse::<Slot>().ok();
            if let Some(base) = rest.strip_suffix(".full").and_then(parse) {
                out.push((base, true, entry.path()));
            } else if let Some(base) = rest.strip_suffix(".delta").and_then(parse) {
                out.push((base, false, entry.path()));
            }
        }
        Ok(out)
    }

    /// The newest full and newest delta snapshot files.
    fn snapshot_files(
        &self,
    ) -> Result<(Option<(Slot, PathBuf)>, Option<(Slot, PathBuf)>), StorageError> {
        let (mut full, mut delta): (Option<(Slot, PathBuf)>, Option<(Slot, PathBuf)>) =
            (None, None);
        for (base, is_full, path) in self.all_snapshot_files()? {
            let slot = if is_full { &mut full } else { &mut delta };
            if slot.as_ref().map_or(true, |(b, _)| base > *b) {
                *slot = Some((base, path));
            }
        }
        Ok((full, delta))
    }

    /// Write `bytes` to `name` atomically: temp file, fsync, rename.
    fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<(), StorageError> {
        let tmp = self.dir.join(format!("{name}.tmp"));
        let path = self.dir.join(name);
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        if self.opts.fsync {
            f.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        self.sync_dir()
    }
}

impl Storage for WalStorage {
    fn append(&mut self, rec: &WalRecord) -> Result<(), StorageError> {
        if self.seg_len >= self.opts.segment_bytes {
            self.rotate()?;
        }
        rec.encode_into(&mut self.scratch);
        let body_len = self.scratch.buf.len();
        let crc = crc32(&self.scratch.buf);
        let mut frame = Vec::with_capacity(8 + body_len);
        frame.extend_from_slice(&(body_len as u32).to_le_bytes());
        frame.extend_from_slice(&crc.to_le_bytes());
        frame.extend_from_slice(&self.scratch.buf);
        // One write_all: a crash mid-call tears at most this frame, and
        // the CRC catches whatever partial prefix made it to disk.
        self.seg.write_all(&frame)?;
        if self.opts.fsync {
            let stall = FSYNC_STALL_NS.load(std::sync::atomic::Ordering::Relaxed);
            if stall > 0 {
                // Injected gray failure: the fsync completes, late.
                std::thread::sleep(std::time::Duration::from_nanos(stall));
            }
            self.seg.sync_data()?;
        }
        self.seg_len += frame.len() as u64;
        Ok(())
    }

    fn replay(&mut self) -> Result<Vec<WalRecord>, StorageError> {
        let mut recs = Vec::new();
        let seqs = Self::segments(&self.dir)?;
        for (i, &seq) in seqs.iter().enumerate() {
            let path = seg_path(&self.dir, seq);
            let mut bytes = Vec::new();
            File::open(&path)?.read_to_end(&mut bytes)?;
            let (segment_recs, valid) = Self::scan(&bytes);
            recs.extend(segment_recs);
            if valid < bytes.len() {
                // Torn/corrupt frame: truncate the segment to its valid
                // prefix and drop every later segment — the conservative
                // prefix is exactly what was durably acked.
                let f = OpenOptions::new().write(true).open(&path)?;
                f.set_len(valid as u64)?;
                f.sync_all()?;
                for &later in &seqs[i + 1..] {
                    fs::remove_file(seg_path(&self.dir, later))?;
                }
                self.sync_dir()?;
                // Re-open the append handle at the repaired tail.
                self.seg_seq = seq;
                self.seg =
                    OpenOptions::new().create(true).append(true).open(&path)?;
                self.seg_len = valid as u64;
                break;
            }
        }
        Ok(recs)
    }

    fn compact(&mut self, live: &[WalRecord]) -> Result<(), StorageError> {
        // Write the live set into a brand-new segment, fsync it, then
        // drop every older segment. A crash between those steps leaves
        // both the old and new copies — replay concatenates them, and
        // role recovery is idempotent over duplicated records (last
        // write wins per key), so this is safe without a manifest.
        let old = Self::segments(&self.dir)?;
        self.rotate()?;
        for rec in live {
            self.append(rec)?;
        }
        self.seg.sync_all()?;
        for seq in old {
            fs::remove_file(seg_path(&self.dir, seq))?;
        }
        self.sync_dir()
    }

    fn put_snapshot(&mut self, base: Slot, bytes: &[u8]) -> Result<(), StorageError> {
        let write_full = self.last_full.is_none() || self.since_full + 1 >= self.opts.full_every;
        if write_full {
            self.write_atomic(&format!("snap-{base}.full"), bytes)?;
            // The new full subsumes every older snapshot file.
            for (old, _, path) in self.all_snapshot_files()? {
                if old < base {
                    fs::remove_file(path)?;
                }
            }
            self.sync_dir()?;
            self.last_full = Some((base, bytes.to_vec()));
            self.since_full = 0;
        } else {
            let (_, full_bytes) = self.last_full.as_ref().unwrap();
            let delta = encode_delta(full_bytes, bytes);
            self.write_atomic(&format!("snap-{base}.delta"), &delta)?;
            // Only the newest delta matters (it carries the whole diff
            // against the full, not an incremental chain).
            for (old, is_full, path) in self.all_snapshot_files()? {
                if !is_full && old < base {
                    fs::remove_file(path)?;
                }
            }
            self.sync_dir()?;
            self.since_full += 1;
        }
        Ok(())
    }

    fn load_snapshot(&mut self) -> Result<Option<(Slot, Vec<u8>)>, StorageError> {
        let (full, delta) = self.snapshot_files()?;
        let Some((full_base, full_path)) = full else { return Ok(None) };
        let mut full_bytes = Vec::new();
        File::open(&full_path)?.read_to_end(&mut full_bytes)?;
        self.last_full = Some((full_base, full_bytes.clone()));
        if let Some((delta_base, delta_path)) = delta {
            if delta_base > full_base {
                let mut delta_bytes = Vec::new();
                File::open(&delta_path)?.read_to_end(&mut delta_bytes)?;
                match apply_delta(&full_bytes, &delta_bytes) {
                    Ok(bytes) => return Ok(Some((delta_base, bytes))),
                    // A corrupt delta falls back to the full snapshot —
                    // same conservative-prefix stance as the record log.
                    Err(_) => return Ok(Some((full_base, full_bytes))),
                }
            }
        }
        Ok(Some((full_base, full_bytes)))
    }

    fn kind(&self) -> &'static str {
        "wal"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Value;
    use crate::round::Round;
    use crate::storage::scratch_dir;

    fn r(epoch: u64) -> Round {
        Round { epoch, proposer: 1, seq: 0 }
    }

    fn vote(slot: Slot) -> WalRecord {
        WalRecord::Vote { slot, vr: r(1), vv: Value::Noop }
    }

    fn no_fsync() -> WalOptions {
        // Tests hammer tiny appends; skipping fsync keeps them fast
        // while exercising identical code paths.
        WalOptions { fsync: false, ..WalOptions::default() }
    }

    #[test]
    fn fsync_stall_knob_arms_and_disarms() {
        // The knob is process-global (set by the nemesis schedule thread,
        // read on every fsync'd append); appends must keep succeeding
        // with it armed, and `0` must fully disarm it.
        let dir = scratch_dir("wal-stall");
        set_fsync_stall_us(1500);
        assert_eq!(fsync_stall_us(), 1500);
        {
            // fsync on: this append takes the stall branch for real.
            let mut w = WalStorage::open(&dir, WalOptions::default()).unwrap();
            w.append(&vote(0)).unwrap();
        }
        set_fsync_stall_us(0);
        assert_eq!(fsync_stall_us(), 0);
        let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
        assert_eq!(w.replay().unwrap().len(), 1);
    }

    #[test]
    fn append_replay_roundtrip_across_reopen() {
        let dir = scratch_dir("wal-rt");
        let recs: Vec<WalRecord> = (0..100).map(vote).collect();
        {
            let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
            for rec in &recs {
                w.append(rec).unwrap();
            }
        }
        let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
        assert_eq!(w.replay().unwrap(), recs);
        // Appends after replay extend the same log.
        w.append(&vote(100)).unwrap();
        let mut w2 = WalStorage::open(&dir, no_fsync()).unwrap();
        assert_eq!(w2.replay().unwrap().len(), 101);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rotation_spills_to_new_segments() {
        let dir = scratch_dir("wal-rot");
        let opts = WalOptions { segment_bytes: 256, ..no_fsync() };
        let mut w = WalStorage::open(&dir, opts).unwrap();
        for i in 0..50 {
            w.append(&vote(i)).unwrap();
        }
        assert!(w.segment_count().unwrap() > 1, "no rotation happened");
        let mut w = WalStorage::open(&dir, opts).unwrap();
        assert_eq!(w.replay().unwrap().len(), 50);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compact_drops_old_segments_and_keeps_live() {
        let dir = scratch_dir("wal-compact");
        let opts = WalOptions { segment_bytes: 256, ..no_fsync() };
        let mut w = WalStorage::open(&dir, opts).unwrap();
        for i in 0..50 {
            w.append(&vote(i)).unwrap();
        }
        let live = vec![WalRecord::Promise { round: r(7) }, vote(49)];
        w.compact(&live).unwrap();
        assert_eq!(w.segment_count().unwrap(), 1);
        let mut w = WalStorage::open(&dir, opts).unwrap();
        assert_eq!(w.replay().unwrap(), live);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated_and_log_reusable() {
        let dir = scratch_dir("wal-torn");
        {
            let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
            for i in 0..10 {
                w.append(&vote(i)).unwrap();
            }
        }
        // Tear the last frame: chop 3 bytes off the segment.
        let path = seg_path(&dir, 0);
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
        let recs = w.replay().unwrap();
        assert_eq!(recs.len(), 9, "torn record replayed");
        assert_eq!(recs, (0..9).map(vote).collect::<Vec<_>>());
        // The repaired log accepts appends and replays them.
        w.append(&vote(99)).unwrap();
        let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
        assert_eq!(w.replay().unwrap().len(), 10);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bit_flip_stops_replay_at_flip() {
        let dir = scratch_dir("wal-flip");
        {
            let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
            for i in 0..10 {
                w.append(&vote(i)).unwrap();
            }
        }
        let path = seg_path(&dir, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let mut w = WalStorage::open(&dir, no_fsync()).unwrap();
        let recs = w.replay().unwrap();
        assert!(recs.len() < 10, "flip not detected");
        assert_eq!(recs, (0..recs.len() as u64).map(vote).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn snapshots_full_then_delta_then_full() {
        let dir = scratch_dir("wal-snap");
        let opts = WalOptions { full_every: 2, ..no_fsync() };
        let mut w = WalStorage::open(&dir, opts).unwrap();
        let mut state = vec![0u8; 4096];
        w.put_snapshot(10, &state).unwrap(); // full
        state[100] = 1;
        w.put_snapshot(20, &state).unwrap(); // delta vs full@10
        assert_eq!(w.load_snapshot().unwrap(), Some((20, state.clone())));
        state[200] = 2;
        w.put_snapshot(30, &state).unwrap(); // full again (full_every=2)
        assert_eq!(w.load_snapshot().unwrap(), Some((30, state.clone())));
        // A fresh open reconstructs from disk alone.
        let mut w = WalStorage::open(&dir, opts).unwrap();
        assert_eq!(w.load_snapshot().unwrap(), Some((30, state.clone())));
        state[300] = 3;
        w.put_snapshot(40, &state).unwrap(); // delta vs full@30
        let mut w = WalStorage::open(&dir, opts).unwrap();
        assert_eq!(w.load_snapshot().unwrap(), Some((40, state)));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
