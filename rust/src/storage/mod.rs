//! Durable storage for role state (DESIGN.md §Durability).
//!
//! The paper's reconfiguration machinery assumes acceptor promises/votes
//! and matchmaker logs survive the crash of the machine that holds them —
//! Phase 1's `P1 ∩ P2` intersection argument (§3.2) and the Figure-7
//! matchmaker merge are both *about* state that outlives a process. This
//! module makes that assumption true on the TCP runtime: every role's
//! durable state is a stream of [`WalRecord`]s behind the [`Storage`]
//! trait, with two implementations:
//!
//! * [`MemStorage`] — an in-memory record log. Keeps the simulator and
//!   model checker fast and allocation-light while still letting crash/
//!   restart tests replay "disk" state into a fresh role instance.
//! * [`wal::WalStorage`] — fsync'd, CRC-framed, length-prefixed segment
//!   files with rotation and watermark-driven compaction (reusing the
//!   §5 GC watermarks). This is what `repro run --data-dir` attaches, so
//!   any role can be `kill -9`'d and rejoin with identical state (the
//!   X10 experiment).
//!
//! The contract every role relies on: [`Storage::append`] returns only
//! after the record is durable (fsync-before-ack), and
//! [`Storage::replay`] returns the longest valid record prefix — a torn
//! tail from a mid-write crash is detected by the CRC frame and cleanly
//! truncated, never replayed as garbage.
//!
//! Record framing in a segment file (all integers little-endian, like
//! [`crate::codec`]):
//!
//! ```text
//! [u32 len][u32 crc32(body)][body: WalRecord wire encoding]
//! ```

use crate::codec::{CodecError, Dec, Enc, Wire};
use crate::config::Configuration;
use crate::msg::Value;
use crate::round::Round;
use crate::{GroupId, NodeId, Slot};
use std::fmt;

pub mod wal;

pub use wal::{WalOptions, WalStorage};

/// Largest record body accepted on replay (matches the codec's own
/// [`Dec::bytes`] cap — anything bigger is treated as corruption).
pub const MAX_RECORD: usize = 64 << 20;

/// A storage failure. I/O errors are fatal for a durability layer (a
/// role that cannot persist must stop acking, so callers `expect` these);
/// corruption is *not* an error — [`Storage::replay`] absorbs it by
/// truncating to the valid prefix.
#[derive(Debug)]
pub enum StorageError {
    /// Underlying filesystem failure.
    Io(std::io::Error),
    /// A record failed to re-encode/decode outside the replay path.
    Codec(String),
}

impl fmt::Display for StorageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StorageError::Io(e) => write!(f, "storage io: {e}"),
            StorageError::Codec(e) => write!(f, "storage codec: {e}"),
        }
    }
}

impl std::error::Error for StorageError {}

impl From<std::io::Error> for StorageError {
    fn from(e: std::io::Error) -> StorageError {
        StorageError::Io(e)
    }
}

/// One durable state transition. Each role appends exactly the records
/// that its safety argument needs to survive a crash (the map lives in
/// DESIGN.md §Durability):
///
/// * acceptor — `Promise` / `Vote` / `Watermark` (Algorithm 2's `r`,
///   per-slot `(vr, vv)`, and the §5.3 chosen-prefix watermark)
/// * matchmaker — `MmEntry` / `MmGcWatermark` / `MmLifecycle` /
///   `MetaPromise` / `MetaVote` (the `(group, round) → config` log,
///   per-group GC watermarks, §6 stop/bootstrap generation, and the
///   meta-Paxos acceptor state)
/// * leader — `LeaderEpoch` (the active-config epoch, so a restarted
///   leader re-elects above every round it ever used)
/// * replica — `Chosen` (the chosen tail above the last snapshot; the
///   snapshot itself goes through [`Storage::put_snapshot`])
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// Acceptor promise: largest round seen.
    Promise { round: Round },
    /// Acceptor per-slot vote.
    Vote { slot: Slot, vr: Round, vv: Value },
    /// Acceptor chosen-prefix watermark (`PrefixPersisted`).
    Watermark { upto: Slot },
    /// Matchmaker log entry: `(group, round) → configuration`.
    MmEntry { group: GroupId, round: Round, config: Configuration },
    /// Matchmaker per-group GC watermark (Algorithm 4).
    MmGcWatermark { group: GroupId, round: Round },
    /// Matchmaker §6 lifecycle: generation + stopped/active flags.
    MmLifecycle { generation: u64, stopped: bool, active: bool },
    /// Leader active-config epoch: the round + configuration activated.
    LeaderEpoch { group: GroupId, round: Round, config: Configuration },
    /// Replica chosen-log entry (the tail above the last snapshot).
    Chosen { slot: Slot, value: Value },
    /// Matchmaker meta-Paxos promise (§6), keyed by the instance's
    /// generation (instance g chooses generation g+1).
    MetaPromise { generation: u64, round: Round },
    /// Matchmaker meta-Paxos vote (§6): the new matchmaker set.
    MetaVote { generation: u64, vr: Round, set: Vec<NodeId> },
}

impl Wire for WalRecord {
    fn enc(&self, e: &mut Enc) {
        match self {
            WalRecord::Promise { round } => {
                e.u8(0);
                round.enc(e);
            }
            WalRecord::Vote { slot, vr, vv } => {
                e.u8(1);
                e.u64(*slot);
                vr.enc(e);
                vv.enc(e);
            }
            WalRecord::Watermark { upto } => {
                e.u8(2);
                e.u64(*upto);
            }
            WalRecord::MmEntry { group, round, config } => {
                e.u8(3);
                e.u32(*group);
                round.enc(e);
                config.enc(e);
            }
            WalRecord::MmGcWatermark { group, round } => {
                e.u8(4);
                e.u32(*group);
                round.enc(e);
            }
            WalRecord::MmLifecycle { generation, stopped, active } => {
                e.u8(5);
                e.u64(*generation);
                e.bool(*stopped);
                e.bool(*active);
            }
            WalRecord::LeaderEpoch { group, round, config } => {
                e.u8(6);
                e.u32(*group);
                round.enc(e);
                config.enc(e);
            }
            WalRecord::Chosen { slot, value } => {
                e.u8(7);
                e.u64(*slot);
                value.enc(e);
            }
            WalRecord::MetaPromise { generation, round } => {
                e.u8(8);
                e.u64(*generation);
                round.enc(e);
            }
            WalRecord::MetaVote { generation, vr, set } => {
                e.u8(9);
                e.u64(*generation);
                vr.enc(e);
                set.enc(e);
            }
        }
    }

    fn dec(d: &mut Dec) -> Result<Self, CodecError> {
        Ok(match d.u8()? {
            0 => WalRecord::Promise { round: Round::dec(d)? },
            1 => WalRecord::Vote { slot: d.u64()?, vr: Round::dec(d)?, vv: Value::dec(d)? },
            2 => WalRecord::Watermark { upto: d.u64()? },
            3 => WalRecord::MmEntry {
                group: d.u32()?,
                round: Round::dec(d)?,
                config: Configuration::dec(d)?,
            },
            4 => WalRecord::MmGcWatermark { group: d.u32()?, round: Round::dec(d)? },
            5 => WalRecord::MmLifecycle {
                generation: d.u64()?,
                stopped: d.bool()?,
                active: d.bool()?,
            },
            6 => WalRecord::LeaderEpoch {
                group: d.u32()?,
                round: Round::dec(d)?,
                config: Configuration::dec(d)?,
            },
            7 => WalRecord::Chosen { slot: d.u64()?, value: Value::dec(d)? },
            8 => WalRecord::MetaPromise { generation: d.u64()?, round: Round::dec(d)? },
            9 => WalRecord::MetaVote {
                generation: d.u64()?,
                vr: Round::dec(d)?,
                set: Vec::<NodeId>::dec(d)?,
            },
            t => return Err(crate::codec::CodecError(format!("unknown wal record tag {t}"))),
        })
    }
}

/// Durable role state behind a uniform interface. `append` must be
/// durable when it returns (that ordering — persist, then ack — is what
/// makes Phase-1 recovery sound, see DESIGN.md §Durability); `replay`
/// returns every surviving record in append order; `compact` atomically
/// replaces the whole log with the given live set (watermark-driven
/// truncation); snapshots are stored out of band from the record log
/// (they can be large).
pub trait Storage: Send + fmt::Debug {
    /// Durably append one record. Returns only once the record would
    /// survive `kill -9`.
    fn append(&mut self, rec: &WalRecord) -> Result<(), StorageError>;

    /// Read back every surviving record, oldest first. Corruption (torn
    /// tail, bit flip) ends the replay at the last valid record — never
    /// an error, never a panic — and repairs the log so subsequent
    /// appends extend the valid prefix.
    fn replay(&mut self) -> Result<Vec<WalRecord>, StorageError>;

    /// Atomically replace the log with `live` (the records still needed
    /// above the GC watermark). Everything older becomes unreachable and
    /// reclaimable.
    fn compact(&mut self, live: &[WalRecord]) -> Result<(), StorageError>;

    /// Durably store the replica snapshot covering slots `< base`.
    fn put_snapshot(&mut self, base: Slot, bytes: &[u8]) -> Result<(), StorageError>;

    /// The most recent snapshot, if any.
    fn load_snapshot(&mut self) -> Result<Option<(Slot, Vec<u8>)>, StorageError>;

    /// `"mem"` or `"wal"` (diagnostics).
    fn kind(&self) -> &'static str;
}

// ---------------------------------------------------------------------
// CRC32 (IEEE), table-driven — used by the WAL frame and the tests that
// corrupt it. No dependency; the table is built at compile time.
// ---------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 of `bytes` (the polynomial zlib/gzip use).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

// ---------------------------------------------------------------------
// MemStorage
// ---------------------------------------------------------------------

/// In-memory [`Storage`]: a `Vec` of records plus the latest snapshot.
/// The simulator's crash/restart tests persist through this — same
/// replay semantics as the WAL, none of the I/O.
#[derive(Debug, Default)]
pub struct MemStorage {
    records: Vec<WalRecord>,
    snapshot: Option<(Slot, Vec<u8>)>,
}

impl MemStorage {
    pub fn new() -> MemStorage {
        MemStorage::default()
    }

    /// Number of live records (tests).
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

impl Storage for MemStorage {
    fn append(&mut self, rec: &WalRecord) -> Result<(), StorageError> {
        self.records.push(rec.clone());
        Ok(())
    }

    fn replay(&mut self) -> Result<Vec<WalRecord>, StorageError> {
        Ok(self.records.clone())
    }

    fn compact(&mut self, live: &[WalRecord]) -> Result<(), StorageError> {
        self.records = live.to_vec();
        Ok(())
    }

    fn put_snapshot(&mut self, base: Slot, bytes: &[u8]) -> Result<(), StorageError> {
        self.snapshot = Some((base, bytes.to_vec()));
        Ok(())
    }

    fn load_snapshot(&mut self) -> Result<Option<(Slot, Vec<u8>)>, StorageError> {
        Ok(self.snapshot.clone())
    }

    fn kind(&self) -> &'static str {
        "mem"
    }
}

// ---------------------------------------------------------------------
// Delta snapshots (full-to-full byte diffs)
// ---------------------------------------------------------------------

/// Encode `new` as a delta against `base`: the new length plus the byte
/// runs that differ. GB-scale tensor state changes sparsely between
/// snapshot ticks, so deltas are small; a delta is applied on top of the
/// last *full* snapshot at load time (the WAL stores `full_every - 1`
/// deltas between fulls).
pub fn encode_delta(base: &[u8], new: &[u8]) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(new.len() as u64);
    let mut runs: Vec<(u64, &[u8])> = Vec::new();
    let mut i = 0usize;
    while i < new.len() {
        let same = i < base.len() && base[i] == new[i];
        if same {
            i += 1;
            continue;
        }
        let start = i;
        while i < new.len() && !(i < base.len() && base[i] == new[i]) {
            i += 1;
        }
        runs.push((start as u64, &new[start..i]));
    }
    e.u32(runs.len() as u32);
    for (off, bytes) in runs {
        e.u64(off);
        e.bytes(bytes);
    }
    e.buf
}

/// Apply a delta produced by [`encode_delta`] to `base`.
pub fn apply_delta(base: &[u8], delta: &[u8]) -> Result<Vec<u8>, CodecError> {
    let mut d = Dec::new(delta);
    let new_len = d.u64()? as usize;
    if new_len > MAX_RECORD {
        return Err(crate::codec::CodecError("delta length too large".into()));
    }
    let mut out = vec![0u8; new_len];
    let n = base.len().min(new_len);
    out[..n].copy_from_slice(&base[..n]);
    let runs = d.u32()?;
    for _ in 0..runs {
        let off = d.u64()? as usize;
        let bytes = d.bytes()?;
        if off + bytes.len() > out.len() {
            return Err(crate::codec::CodecError("delta run out of range".into()));
        }
        out[off..off + bytes.len()].copy_from_slice(&bytes);
    }
    if !d.done() {
        return Err(crate::codec::CodecError("trailing delta bytes".into()));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Test support
// ---------------------------------------------------------------------

/// A fresh scratch directory under the system temp dir, unique per
/// process and call (no wall clock — determinism lint). Used by the WAL
/// tests and benches; callers clean up with `remove_dir_all`.
pub fn scratch_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "matchmaker-{tag}-{}-{n}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(epoch: u64) -> Round {
        Round { epoch, proposer: 1, seq: 0 }
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Promise { round: r(3) },
            WalRecord::Vote { slot: 7, vr: r(3), vv: Value::Noop },
            WalRecord::Watermark { upto: 4 },
            WalRecord::MmEntry {
                group: 2,
                round: r(1),
                config: Configuration::majority(5, vec![10, 11, 12]),
            },
            WalRecord::MmGcWatermark { group: 2, round: r(1) },
            WalRecord::MmLifecycle { generation: 9, stopped: true, active: false },
            WalRecord::LeaderEpoch {
                group: 0,
                round: r(2),
                config: Configuration::majority(6, vec![10, 11, 12]),
            },
            WalRecord::Chosen {
                slot: 11,
                value: Value::Cmd(crate::msg::Command {
                    client: 90,
                    seq: 2,
                    payload: vec![1, 2, 3],
                }),
            },
            WalRecord::MetaPromise { generation: 8, round: r(4) },
            WalRecord::MetaVote { generation: 8, vr: r(4), set: vec![3, 4, 5] },
        ]
    }

    #[test]
    fn wal_records_roundtrip() {
        for rec in sample_records() {
            let bytes = rec.encode();
            assert_eq!(WalRecord::decode(&bytes).unwrap(), rec);
            // Truncation rejection, like the message codec.
            for cut in 0..bytes.len() {
                assert!(WalRecord::decode(&bytes[..cut]).is_err());
            }
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // zlib's published test vector.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn mem_storage_roundtrip_and_compact() {
        let mut s = MemStorage::new();
        for rec in sample_records() {
            s.append(&rec).unwrap();
        }
        assert_eq!(s.replay().unwrap(), sample_records());
        let live = vec![WalRecord::Watermark { upto: 9 }];
        s.compact(&live).unwrap();
        assert_eq!(s.replay().unwrap(), live);
        s.put_snapshot(5, b"snapbytes").unwrap();
        assert_eq!(s.load_snapshot().unwrap(), Some((5, b"snapbytes".to_vec())));
    }

    #[test]
    fn delta_roundtrip() {
        let base = vec![0u8; 1000];
        let mut new = base.clone();
        new[17] = 9;
        new[500..510].copy_from_slice(&[7; 10]);
        new.extend_from_slice(&[1, 2, 3]); // grows
        let delta = encode_delta(&base, &new);
        assert!(delta.len() < 100, "delta not sparse: {}", delta.len());
        assert_eq!(apply_delta(&base, &delta).unwrap(), new);
        // Shrinking state round-trips too.
        let small = vec![5u8; 10];
        let delta = encode_delta(&new, &small);
        assert_eq!(apply_delta(&new, &delta).unwrap(), small);
    }

    #[test]
    fn delta_rejects_garbage() {
        assert!(apply_delta(b"base", &[0xff; 3]).is_err());
        let delta = encode_delta(b"aaaa", b"bbbb");
        // Applying against the wrong base still yields *something* of the
        // right length (deltas are positional), but corrupt framing errors.
        assert!(apply_delta(b"", &delta[..delta.len() - 1]).is_err());
    }
}
