//! Small utilities: a deterministic RNG and statistics helpers.
//!
//! We implement our own tiny PRNG (SplitMix64 seeding an xoshiro256**) so
//! that simulated executions are bit-for-bit reproducible across platforms
//! and independent of external crate version bumps. The simulator, the
//! thrifty quorum sampler, and the workload generators all draw from this.

/// A deterministic xoshiro256** PRNG seeded via SplitMix64.
///
/// Not cryptographically secure — it exists purely for reproducible
/// simulation. Quality is more than sufficient for delay jitter, drop
/// decisions, and quorum sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create an RNG from a 64-bit seed. Two RNGs with the same seed produce
    /// identical streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire-style rejection-free enough for simulation purposes.
        (self.next_u64() as u128 * n as u128 >> 64) as u64
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        p > 0.0 && self.next_f64() < p
    }

    /// Choose `k` distinct elements from `items` (Fisher–Yates prefix).
    pub fn sample<T: Clone>(&mut self, items: &[T], k: usize) -> Vec<T> {
        let mut pool: Vec<T> = items.to_vec();
        let k = k.min(pool.len());
        for i in 0..k {
            let j = i + self.gen_range((pool.len() - i) as u64) as usize;
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Split off an independent RNG stream (for per-node determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// The raw generator state (model-checker state fingerprinting: two
    /// nodes whose RNGs diverged can behave differently later, so the
    /// state must participate in equality).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }
}

/// One SplitMix64 step: a stateless 64-bit mixer. Used where a
/// deterministic hash of a few identifiers must stand in for randomness
/// (e.g. retry-jitter from `(client, seq, attempt)`) without consuming a
/// stateful [`Rng`] stream that other draws depend on.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A tiny FNV-1a 64-bit hasher for model-checker state fingerprints.
///
/// Hand-rolled for the same reason as [`Rng`]: fingerprints must be
/// bit-for-bit stable across platforms and toolchain bumps (checked-in
/// traces and dedup counts in CI depend on them), which rules out
/// `DefaultHasher` (its algorithm is explicitly unspecified).
#[derive(Clone, Debug)]
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write(s.as_bytes());
        // Length-prefix-free framing: terminate so "ab"+"c" != "a"+"bc".
        self.write(&[0xff]);
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Fnv::new()
    }
}

/// Summary statistics used throughout the evaluation harness: the paper
/// reports medians, interquartile ranges, and standard deviations (Tables
/// 1 and 2), plus p95 shading in the timeline figures; the open-loop
/// workload summaries report p99 tail latency.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Stats {
    pub count: usize,
    pub median: f64,
    pub p25: f64,
    pub p75: f64,
    pub p95: f64,
    pub p99: f64,
    pub iqr: f64,
    pub mean: f64,
    pub stdev: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute [`Stats`] over a sample. Returns `None` for an empty sample.
pub fn stats(samples: &[f64]) -> Option<Stats> {
    if samples.is_empty() {
        return None;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        // Nearest-rank with linear interpolation.
        let idx = p * (v.len() - 1) as f64;
        let lo = idx.floor() as usize;
        let hi = idx.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
        }
    };
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
    let (p25, p75) = (pct(0.25), pct(0.75));
    Some(Stats {
        count: v.len(),
        median: pct(0.5),
        p25,
        p75,
        p95: pct(0.95),
        p99: pct(0.99),
        iqr: p75 - p25,
        mean,
        stdev: var.sqrt(),
        min: v[0],
        max: *v.last().unwrap(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_streams_differ_by_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(9);
        assert!(!r.chance(0.0));
        assert!(r.chance(1.0));
    }

    #[test]
    fn sample_distinct() {
        let mut r = Rng::new(3);
        let items: Vec<u32> = (0..10).collect();
        let s = r.sample(&items, 4);
        assert_eq!(s.len(), 4);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 4);
    }

    #[test]
    fn sample_k_larger_than_pool() {
        let mut r = Rng::new(3);
        let items = [1u32, 2, 3];
        assert_eq!(r.sample(&items, 10).len(), 3);
    }

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.median, 3.0);
        assert_eq!(s.p25, 2.0);
        assert_eq!(s.p75, 4.0);
        assert_eq!(s.iqr, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!(s.p99 >= s.p95 && s.p99 <= s.max);
    }

    #[test]
    fn p99_tracks_tail() {
        // 99 fast samples and one slow one: p99 must reach into the tail.
        let mut v = vec![1.0; 99];
        v.push(100.0);
        let s = stats(&v).unwrap();
        assert!(s.p99 > 1.0, "p99 {} ignored the tail", s.p99);
        assert_eq!(s.median, 1.0);
    }

    #[test]
    fn stats_empty() {
        assert!(stats(&[]).is_none());
    }

    #[test]
    fn stats_single() {
        let s = stats(&[7.5]).unwrap();
        assert_eq!(s.median, 7.5);
        assert_eq!(s.stdev, 0.0);
    }
}
