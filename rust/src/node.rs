//! The sans-io node abstraction.
//!
//! Every protocol role is a deterministic state machine implementing
//! [`Node`]: it reacts to delivered messages and expired timers by mutating
//! local state and pushing [`Effects`] — outbound messages, new timers, and
//! *announcements* (externally observable facts used by the harness for
//! metrics and by the test suite for invariant checking; they are **not**
//! part of the protocol).
//!
//! The same role implementations run under the deterministic simulator
//! ([`crate::sim`]) and the TCP runtime ([`crate::net`]).

use crate::config::Configuration;
use crate::msg::{MmLog, Msg, Value};
use crate::round::Round;
use crate::{GroupId, NodeId, Slot, Time};
use std::collections::BTreeMap;

/// Timers a node can request. The driver calls [`Node::on_timer`] when one
/// expires; a node distinguishes stale timers itself (via generation
/// counters carried in the variant).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Timer {
    /// Client: resend the outstanding request if no reply arrived. `gen`
    /// guards against stale timers (only the most recently armed timer for
    /// a request is live — re-sends would otherwise multiply timers).
    ClientResend { seq: u64, generation: u64 },
    /// Leader: re-send Phase2A to all acceptors for a slot whose thrifty
    /// quorum did not respond (§8.1 thriftiness failure path).
    Phase2Retry { slot: Slot, generation: u64 },
    /// Leader/proposer: resend matchmaking / phase1 messages.
    PhaseResend { generation: u64 },
    /// Leader: periodic scan of in-flight slots (thrifty fallback +
    /// reconfiguration-stall rescue) — one timer for the whole window
    /// instead of one per slot.
    Phase2Watchdog,
    /// Leader: flush a partially filled command batch that has waited
    /// `OptFlags::batch_delay` (Phase 2 batching).
    BatchFlush,
    /// Leader: emit a heartbeat to peers.
    HeartbeatTick,
    /// Replica: take a periodic state-machine snapshot and truncate the
    /// chosen log below the snapshot watermark
    /// ([`crate::config::SnapshotSpec`]).
    SnapshotTick,
    /// Replica: re-issue an unanswered `SnapshotRequest` (catch-up must
    /// survive a lost response even when no client traffic is flowing to
    /// trigger another `CatchUp` hint).
    CatchupRetry,
    /// Shard router client: resend one in-flight request of a per-group
    /// lane (seq spaces are per lane, so the group disambiguates).
    ShardResend { group: GroupId, seq: u64, generation: u64 },
    /// Client: resend an outstanding read-only query (reads live in
    /// their own per-client seq space; see [`crate::roles::replica`]).
    ReadResend { seq: u64, generation: u64 },
    /// Shard router client: resend one in-flight read of a per-group
    /// lane.
    ShardReadResend { group: GroupId, seq: u64, generation: u64 },
    /// Leader: renew the read lease with the active configuration's
    /// acceptors ([`crate::config::LeaseSpec::refresh`] cadence).
    LeaseRenewTick,
    /// Leader: the post-election lease fence expired — outstanding
    /// leases granted by any previous leader are dead, so the new
    /// configuration may start choosing commands (DESIGN.md §Reads).
    LeaseFence,
    /// Replica: re-drive pending reads (re-send an unanswered
    /// ReadIndex request, fall lapsed-lease reads back to the
    /// ReadIndex path, expire abandoned entries).
    ReadIndexRetry,
    /// Election: check whether the leader's heartbeats stopped.
    LeaderCheck,
    /// Generic scheduled wakeup used by harness-driven roles.
    Wakeup { tag: u64 },
}

/// Externally observable protocol events. The simulator's observer records
/// these for metrics (e.g. reconfiguration-to-active latency) and safety
/// checking (at most one value chosen per slot).
#[derive(Clone, PartialEq, Debug)]
pub enum Announce {
    /// A value was chosen in `slot` of consensus group `group`
    /// (leader-observed quorum of Phase2B). Slot numbers are per group:
    /// safety is at-most-one value per `(group, slot)`.
    Chosen { group: GroupId, slot: Slot, round: Round, value: Value },
    /// A replica executed `slot`, producing `result`.
    Executed { slot: Slot, replica: NodeId },
    /// The group's leader finished matchmaking for `round`: the new
    /// configuration is active (paper: "active within a millisecond").
    ConfigActive { group: GroupId, round: Round, config_id: u64 },
    /// GarbageB quorum reached for `round` in `group`: all of the group's
    /// configurations below it are retired and their acceptors may shut
    /// down (paper: "GC'd within five milliseconds").
    ConfigRetired { group: GroupId, round: Round },
    /// A leader became steady (Phase 2) in `round`.
    LeaderSteady { round: Round },
    /// The matchmaker set was reconfigured (§6).
    MatchmakersReconfigured { matchmakers: Vec<NodeId> },
    /// Fast Paxos: coordinator observed a fast-round choice.
    FastChosen { round: Round, value: Value },
    /// A replica snapshotted its state machine at `upto` (exclusive) and
    /// truncated its chosen log below the retained tail.
    SnapshotTaken { replica: NodeId, upto: Slot },
    /// A replica installed a peer's snapshot covering slots `< base`
    /// (crash-rejoin / lagging-node catch-up).
    SnapshotInstalled { replica: NodeId, base: Slot },
    /// A client received `Msg::Busy` pushback for request `seq`
    /// (admission control, DESIGN.md §Overload). Observation-only — in
    /// TCP runs the client's counters live on another thread, so this is
    /// how tests see that pushback actually traversed the wire.
    BusyObserved { client: NodeId, seq: u64 },

    // ---- Model-checker probes (crate::check). These expose protocol
    // facts the invariant catalog needs but the metrics layer does not;
    // like all announcements they are observation-only, never wire
    // messages, so they have no codec tags. ----
    /// A matchmaker answered `MatchA⟨i, C⟩` with a `MatchB` (Algorithm 1).
    /// The refusal discipline makes the answered rounds per
    /// (matchmaker, group) non-decreasing — the matchmaker-monotonic
    /// invariant checks exactly that.
    MatchAnswered { group: GroupId, round: Round },
    /// A matchmaker raised (or confirmed) its per-group GC watermark to
    /// `round` while handling `GarbageA` (Algorithm 4).
    MmGc { group: GroupId, round: Round },
    /// A leader merged `f+1` stopped matchmaker states (§6, Figure 7):
    /// the inputs, the merged log, and the merged per-group watermarks.
    /// The mm-merge invariant recomputes the merge from the inputs and
    /// compares.
    MmMerged {
        inputs: Vec<(MmLog, BTreeMap<GroupId, Round>)>,
        merged: MmLog,
        watermarks: BTreeMap<GroupId, Round>,
    },
    /// The full configuration activated for `round` (a superset of
    /// `ConfigActive`, which only carries the id): the
    /// quorum-intersection invariant checks every Phase-1 quorum of
    /// `config` intersects every Phase-2 quorum (§3.2, Theorem 1's
    /// precondition).
    QuorumConfig { group: GroupId, round: Round, config: Configuration },
    /// The leader broadcast a read-lease grant valid until `valid_until`
    /// under `round` (DESIGN.md §Reads).
    LeaseGranted { round: Round, valid_until: Time },
    /// A new leader's post-election lease fence lifted for `round`: every
    /// grant issued under any lower round must already have expired —
    /// the lease-fence invariant.
    FenceLifted { round: Round },
    /// The leader compacted its log below `below`; `durable` is the
    /// f+1-replica-persisted watermark at that moment (`below ≤ durable`
    /// or a not-yet-executed value could be lost — watermark-order
    /// invariant).
    LogTruncated { group: GroupId, below: Slot, durable: Slot },
    /// A replica truncated its chosen log below `below`; `exec` is its
    /// executed watermark (`below ≤ exec`: never GC an unexecuted slot).
    ReplicaTruncated { replica: NodeId, below: Slot, exec: Slot },
    /// The simulator replaced the node (crash recovery / fresh machine):
    /// per-node monotonicity checks reset here. Synthesized by
    /// [`crate::sim::Sim::replace_node`], never by a role.
    NodeRestarted { node: NodeId },

    // ---- Durability probes (crate::storage; DESIGN.md §Durability).
    // Emitted only when a role has a Storage attached — the default
    // in-memory deployments produce none of these. ----
    /// An acceptor durably logged a promise for `round` (WAL appended
    /// and fsync'd) before its Phase-1/lease ack left the node.
    DurablePromise { node: NodeId, round: Round },
    /// An acceptor durably logged a vote `(slot, vr)` before its
    /// Phase-2B left the node.
    DurableVote { node: NodeId, slot: Slot, vr: Round },
    /// An acceptor durably advanced its chosen-prefix watermark to
    /// `upto`: votes below it are compacted from the log (they are
    /// durable on f+1 replicas), so the recovery-soundness shadow
    /// forgets them too.
    AcceptorWatermark { node: NodeId, upto: Slot },
    /// An acceptor finished WAL replay after a crash: its restored
    /// promise, watermark, and per-slot vote rounds. The
    /// recovery-soundness invariant checks this against the durable
    /// shadow accumulated from the probes above — a restored state
    /// below anything durably acked (an "un-promise") is a safety bug.
    AcceptorRecovered {
        node: NodeId,
        round: Option<Round>,
        watermark: Slot,
        votes: Vec<(Slot, Round)>,
    },
}

/// The output of one activation of a node.
#[derive(Default, Debug)]
pub struct Effects {
    /// Outbound messages `(dst, msg)`.
    pub msgs: Vec<(NodeId, Msg)>,
    /// Timer requests `(delay, timer)` relative to "now".
    pub timers: Vec<(Time, Timer)>,
    /// Observable events (metrics + invariant checking only).
    pub announces: Vec<Announce>,
}

impl Effects {
    pub fn new() -> Effects {
        Effects::default()
    }

    /// Queue a message to `dst`.
    pub fn send(&mut self, dst: NodeId, msg: Msg) {
        self.msgs.push((dst, msg));
    }

    /// Queue the same message to every destination.
    pub fn broadcast(&mut self, dsts: &[NodeId], msg: &Msg) {
        for &d in dsts {
            self.msgs.push((d, msg.clone()));
        }
    }

    /// Broadcast by value: clone for all destinations but the last,
    /// which receives `msg` itself. On fan-out hot paths (`Chosen` to
    /// the replica group, Phase2A watchdog re-sends) this saves one
    /// full message clone per broadcast over building a template and
    /// calling [`Effects::broadcast`] — measurable when the value is a
    /// command batch. No-op (message dropped) when `dsts` is empty.
    pub fn broadcast_move(&mut self, dsts: &[NodeId], msg: Msg) {
        let Some((&last, rest)) = dsts.split_last() else {
            return;
        };
        for &d in rest {
            self.msgs.push((d, msg.clone()));
        }
        self.msgs.push((last, msg));
    }

    /// Request a timer `delay` ns from now.
    pub fn timer(&mut self, delay: Time, t: Timer) {
        self.timers.push((delay, t));
    }

    /// Record an announcement.
    pub fn announce(&mut self, a: Announce) {
        self.announces.push(a);
    }

    /// Merge another effects batch into this one (helper for roles that
    /// compose sub-state-machines, e.g. the leader driving GC).
    pub fn absorb(&mut self, other: Effects) {
        self.msgs.extend(other.msgs);
        self.timers.extend(other.timers);
        self.announces.extend(other.announces);
    }
}

/// A protocol role. Implementations must be deterministic: identical
/// message/timer sequences (and identical seeds for roles that randomize,
/// e.g. thrifty quorum sampling) produce identical effects.
pub trait Node: Send {
    /// A message from `from` was delivered at time `now`.
    fn on_msg(&mut self, now: Time, from: NodeId, msg: Msg, fx: &mut Effects);

    /// A previously requested timer expired at time `now`.
    fn on_timer(&mut self, now: Time, timer: Timer, fx: &mut Effects);

    /// Called once when the node starts (or restarts after a crash).
    /// Default: no-op.
    fn on_start(&mut self, _now: Time, _fx: &mut Effects) {}

    /// Role name for logs/metrics.
    fn role(&self) -> &'static str;

    /// A canonical, time-free rendering of the node's protocol state,
    /// consumed by the model checker's state fingerprinting
    /// ([`crate::sim::Sim::fingerprint`]). Two nodes with equal reprs
    /// must behave identically on any future message/timer sequence, so
    /// implementations include all protocol state but exclude wall-era
    /// artifacts (absolute timestamps, metrics counters) — including
    /// those would only split equivalent states, never merge distinct
    /// ones. `None` (the default) excludes the node from fingerprints,
    /// appropriate for roles outside the checked protocol core
    /// (workload clients, harness pumps).
    fn state_repr(&self) -> Option<String> {
        None
    }

    /// Downcasting hook so harnesses can drive control-plane actions
    /// (e.g. "leader: reconfigure to these acceptors now") that in a real
    /// deployment arrive over an admin RPC.
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effects_accumulate() {
        let mut fx = Effects::new();
        fx.send(1, Msg::StopA);
        fx.broadcast(&[2, 3], &Msg::BootstrapAck);
        fx.timer(100, Timer::HeartbeatTick);
        fx.announce(Announce::LeaderSteady { round: Round::first(0, 0) });
        assert_eq!(fx.msgs.len(), 3);
        assert_eq!(fx.timers.len(), 1);
        assert_eq!(fx.announces.len(), 1);

        let mut fx2 = Effects::new();
        fx2.send(9, Msg::StopA);
        fx2.absorb(fx);
        assert_eq!(fx2.msgs.len(), 4);
        assert_eq!(fx2.msgs[0].0, 9);
    }

    #[test]
    fn broadcast_move_reaches_every_destination() {
        let mut fx = Effects::new();
        fx.broadcast_move(&[4, 5, 6], Msg::BootstrapAck);
        assert_eq!(fx.msgs.len(), 3);
        for (i, d) in [4, 5, 6].into_iter().enumerate() {
            assert_eq!(fx.msgs[i], (d, Msg::BootstrapAck));
        }
        // Empty destination list: the message is dropped, not misrouted.
        let mut fx2 = Effects::new();
        fx2.broadcast_move(&[], Msg::BootstrapAck);
        assert!(fx2.msgs.is_empty());
    }
}
