//! Workload specifications: what each client in a cluster does.
//!
//! The paper's evaluation (§8.1) uses only *closed-loop* clients: one
//! outstanding request per client, the next issued as soon as the reply
//! arrives. That caps measurable throughput at `n_clients / latency` and
//! hides saturation behavior. A [`WorkloadSpec`] generalizes the client
//! role three ways while keeping the paper's numbers reproducible via
//! [`WorkloadSpec::closed_loop`]:
//!
//! * **closed-loop** — `window = 1`, the §8.1 client.
//! * **pipelined** — a closed loop with a window of `k` outstanding
//!   requests (per-client FIFO ordering is preserved end to end; see
//!   [`crate::roles::sequencer`]).
//! * **open-loop** — requests *arrive* at a configured rate (fixed
//!   interval or deterministic-Poisson) independent of completions, with
//!   a bounded in-flight window; arrivals beyond the bound queue at the
//!   client. Clients record offered vs completed rates, so saturation
//!   and tail latency under overload become measurable.
//!
//! A spec is deployment-wide: the same `WorkloadSpec` is handed to every
//! client of a cluster (payloads may still differ per client via
//! [`PayloadSpec::PerClient`]). Specs are plain data — the harness
//! builder ([`crate::harness::Cluster::builder`]), the cluster config
//! text format (`workload = ...` in [`crate::config::DeploymentConfig`]),
//! and the `repro run --role client` CLI flags all construct them.

use crate::{NodeId, Time, MS, SEC};

/// Hard cap on any client's in-flight window. Replicas cache this many
/// recent per-client results for retry re-replies
/// ([`crate::roles::replica::RESULT_CACHE`] mirrors it); a window larger
/// than the cache could leave a lost reply unanswerable forever, so the
/// spec constructors clamp to it.
pub const MAX_IN_FLIGHT: usize = 128;

/// Default bound on the open-loop client-side arrival queue (arrivals
/// beyond `max_in_flight` that are waiting to be dispatched). Generous —
/// transient bursts never hit it — but finite, so a run driven past
/// saturation sheds (counted in the client's `abandoned` counter)
/// instead of growing the backlog without bound.
pub const DEFAULT_QUEUE_CAP: usize = 4096;

/// How a client decides when to issue the next request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadMode {
    /// Keep `window` requests outstanding; issue a new one the moment a
    /// reply frees a slot. `window == 1` is the paper's §8.1 client;
    /// `window > 1` is the pipelined client.
    ClosedLoop {
        /// Outstanding-request window (>= 1).
        window: usize,
    },
    /// Requests arrive every `interval` ns regardless of completions
    /// (fixed-rate when `poisson` is false; with `poisson`, inter-arrival
    /// gaps are exponentially distributed with mean `interval`, drawn
    /// from the client's deterministic seeded RNG). At most
    /// `max_in_flight` requests are on the wire at once; arrivals beyond
    /// that queue client-side, and their latency is measured from
    /// *arrival*, so queueing delay under overload is visible.
    OpenLoop {
        /// Mean inter-arrival gap in ns (`SEC / rate`).
        interval: Time,
        /// Exponential (deterministic-Poisson) inter-arrival gaps.
        poisson: bool,
        /// In-flight bound; `1` disables pipelining, larger values let
        /// the arrival process run ahead of the commit pipeline.
        max_in_flight: usize,
        /// Bound on the client-side arrival queue (arrivals waiting for
        /// an in-flight slot). An arrival past a full queue is dropped
        /// and counted in the client's `abandoned` counter, so past
        /// saturation the backlog — and with it queueing latency and
        /// memory — stays bounded. Default [`DEFAULT_QUEUE_CAP`].
        queue_cap: usize,
    },
}

/// What bytes each command carries.
#[derive(Clone, Debug, PartialEq)]
pub enum PayloadSpec {
    /// Every command from every client carries these bytes (the paper
    /// uses a one-byte no-op).
    Fixed(Vec<u8>),
    /// Per-client payloads computed from the client's node id (e.g. the
    /// tensor workload, where each client streams a distinct command).
    /// Harness-only: not representable in the config text format.
    PerClient(fn(NodeId) -> Vec<u8>),
}

impl PayloadSpec {
    /// The payload for `client`.
    pub fn bytes_for(&self, client: NodeId) -> Vec<u8> {
        match self {
            PayloadSpec::Fixed(b) => b.clone(),
            PayloadSpec::PerClient(f) => f(client),
        }
    }
}

/// A complete client workload description.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    /// When the client issues requests (closed loop / open loop).
    pub mode: WorkloadMode,
    /// What bytes each command carries.
    pub payload: PayloadSpec,
    /// What bytes read-only queries carry (interpreted by
    /// [`crate::statemachine::StateMachine::query`]). Defaults to an
    /// empty payload — the register/counter queries ignore it; kv
    /// workloads set an encoded `get`.
    pub read_payload: PayloadSpec,
    /// Fraction of requests issued as linearizable read-only queries
    /// (`0.0` = the all-write default; `0.9` = the X7 read-heavy mix).
    /// Reads are served by replicas off the Phase-2 hot path when the
    /// client knows the replica set ([`crate::roles::Client::replicas`]);
    /// otherwise the read payload is routed through the log like any
    /// command, which is the all-through-Phase-2 baseline.
    pub read_fraction: f64,
    /// Start issuing at this time (0 = immediately on start).
    pub start_at: Time,
    /// Stop issuing new requests — and retrying lost ones — at this time
    /// (`u64::MAX` = never).
    pub stop_at: Time,
    /// Per-request resend timeout if no reply arrives.
    pub resend_after: Time,
    /// Size of the key space a shard-routing client draws from
    /// ([`crate::roles::router::ShardClient`]: each request's key is
    /// drawn uniformly from `0..keys` and hashed to its home consensus
    /// group, so requests spread across every group of a sharded
    /// deployment). Single-group clients ignore it. Default 1024.
    pub keys: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec::closed_loop()
    }
}

impl WorkloadSpec {
    fn base(mode: WorkloadMode) -> WorkloadSpec {
        WorkloadSpec {
            mode,
            payload: PayloadSpec::Fixed(vec![0u8]),
            read_payload: PayloadSpec::Fixed(Vec::new()),
            read_fraction: 0.0,
            start_at: 0,
            stop_at: u64::MAX,
            resend_after: 100 * MS,
            keys: 1024,
        }
    }

    /// The paper-faithful §8.1 client: one outstanding request.
    pub fn closed_loop() -> WorkloadSpec {
        WorkloadSpec::base(WorkloadMode::ClosedLoop { window: 1 })
    }

    /// A closed loop with `window` outstanding requests (per-client FIFO
    /// order preserved; clamped to [`MAX_IN_FLIGHT`]).
    pub fn pipelined(window: usize) -> WorkloadSpec {
        WorkloadSpec::base(WorkloadMode::ClosedLoop { window: clamp_window(window) })
    }

    /// Fixed-rate open loop: one arrival every `SEC / rate_per_sec` ns,
    /// default in-flight bound 64.
    pub fn open_loop(rate_per_sec: f64) -> WorkloadSpec {
        WorkloadSpec::base(WorkloadMode::OpenLoop {
            interval: rate_to_interval(rate_per_sec),
            poisson: false,
            max_in_flight: 64,
            queue_cap: DEFAULT_QUEUE_CAP,
        })
    }

    /// Deterministic-Poisson open loop: exponential inter-arrival gaps
    /// with mean `SEC / rate_per_sec` ns, drawn from the client's seeded
    /// RNG (identical seeds give identical arrival schedules).
    pub fn open_loop_poisson(rate_per_sec: f64) -> WorkloadSpec {
        WorkloadSpec::base(WorkloadMode::OpenLoop {
            interval: rate_to_interval(rate_per_sec),
            poisson: true,
            max_in_flight: 64,
            queue_cap: DEFAULT_QUEUE_CAP,
        })
    }

    /// Payload of `n` zero bytes for every command.
    pub fn payload_bytes(mut self, n: usize) -> WorkloadSpec {
        self.payload = PayloadSpec::Fixed(vec![0u8; n.max(1)]);
        self
    }

    /// Exact payload bytes for every command.
    pub fn payload(mut self, bytes: Vec<u8>) -> WorkloadSpec {
        self.payload = PayloadSpec::Fixed(bytes);
        self
    }

    /// Per-client payload generator (see [`PayloadSpec::PerClient`]).
    pub fn payload_with(mut self, f: fn(NodeId) -> Vec<u8>) -> WorkloadSpec {
        self.payload = PayloadSpec::PerClient(f);
        self
    }

    /// Fraction of requests issued as linearizable reads (clamped to
    /// `[0, 1]`; default 0: the paper's all-write workload).
    pub fn read_fraction(mut self, f: f64) -> WorkloadSpec {
        self.read_fraction = if f.is_finite() { f.clamp(0.0, 1.0) } else { 0.0 };
        self
    }

    /// Exact payload bytes for every read-only query (default: empty).
    pub fn read_payload(mut self, bytes: Vec<u8>) -> WorkloadSpec {
        self.read_payload = PayloadSpec::Fixed(bytes);
        self
    }

    /// Per-client read payload generator.
    pub fn read_payload_with(mut self, f: fn(NodeId) -> Vec<u8>) -> WorkloadSpec {
        self.read_payload = PayloadSpec::PerClient(f);
        self
    }

    /// Begin issuing at `t` (default 0: immediately on start).
    pub fn start_at(mut self, t: Time) -> WorkloadSpec {
        self.start_at = t;
        self
    }

    /// Stop issuing — and retrying — at `t` (default: never).
    pub fn stop_at(mut self, t: Time) -> WorkloadSpec {
        self.stop_at = t;
        self
    }

    /// Per-request resend timeout when no reply arrives (default 100 ms).
    pub fn resend_after(mut self, t: Time) -> WorkloadSpec {
        self.resend_after = t.max(1);
        self
    }

    /// Key-space size for shard routing (clamped to ≥ 1; default 1024).
    /// Only meaningful for [`crate::roles::router::ShardClient`]-driven
    /// deployments; single-group clients ignore it.
    pub fn keys(mut self, n: u64) -> WorkloadSpec {
        self.keys = n.max(1);
        self
    }

    /// Set the in-flight bound: the closed-loop window, or the open-loop
    /// `max_in_flight`. Clamped to `1..=`[`MAX_IN_FLIGHT`].
    pub fn max_in_flight(mut self, k: usize) -> WorkloadSpec {
        let k = clamp_window(k);
        match &mut self.mode {
            WorkloadMode::ClosedLoop { window } => *window = k,
            WorkloadMode::OpenLoop { max_in_flight, .. } => *max_in_flight = k,
        }
        self
    }

    /// Bound the open-loop arrival queue at `n` waiting arrivals
    /// (clamped to ≥ 1; no-op for closed-loop modes). Default
    /// [`DEFAULT_QUEUE_CAP`].
    pub fn queue_cap(mut self, n: usize) -> WorkloadSpec {
        if let WorkloadMode::OpenLoop { queue_cap, .. } = &mut self.mode {
            *queue_cap = n.max(1);
        }
        self
    }

    /// The in-flight bound, whichever mode.
    pub fn in_flight_bound(&self) -> usize {
        match self.mode {
            WorkloadMode::ClosedLoop { window } => window,
            WorkloadMode::OpenLoop { max_in_flight, .. } => max_in_flight,
        }
    }

    /// Offered arrival rate per second (`None` for closed-loop modes,
    /// whose offered rate is completion-driven).
    pub fn offered_rate(&self) -> Option<f64> {
        match self.mode {
            WorkloadMode::ClosedLoop { .. } => None,
            WorkloadMode::OpenLoop { interval, .. } => Some(SEC as f64 / interval as f64),
        }
    }
}

fn clamp_window(k: usize) -> usize {
    k.clamp(1, MAX_IN_FLIGHT)
}

fn rate_to_interval(rate_per_sec: f64) -> Time {
    assert!(
        rate_per_sec.is_finite() && rate_per_sec > 0.0,
        "open-loop rate must be positive, got {rate_per_sec}"
    );
    ((SEC as f64 / rate_per_sec) as Time).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_is_paper_default() {
        let w = WorkloadSpec::closed_loop();
        assert_eq!(w.mode, WorkloadMode::ClosedLoop { window: 1 });
        assert_eq!(w.payload, PayloadSpec::Fixed(vec![0u8]));
        assert_eq!(w.start_at, 0);
        assert_eq!(w.stop_at, u64::MAX);
        assert_eq!(w.in_flight_bound(), 1);
        assert_eq!(w.offered_rate(), None);
    }

    #[test]
    fn pipelined_sets_window() {
        assert_eq!(WorkloadSpec::pipelined(8).in_flight_bound(), 8);
        assert_eq!(WorkloadSpec::pipelined(0).in_flight_bound(), 1);
        assert_eq!(
            WorkloadSpec::closed_loop().max_in_flight(4).mode,
            WorkloadMode::ClosedLoop { window: 4 }
        );
    }

    #[test]
    fn windows_clamped_to_replica_result_cache() {
        // Larger windows could outrun the replicas' retry-result cache
        // (a lost reply would become unanswerable), so they clamp.
        assert_eq!(WorkloadSpec::pipelined(100_000).in_flight_bound(), MAX_IN_FLIGHT);
        assert_eq!(
            WorkloadSpec::open_loop(100.0).max_in_flight(100_000).in_flight_bound(),
            MAX_IN_FLIGHT
        );
    }

    #[test]
    fn open_loop_rate_roundtrips() {
        let w = WorkloadSpec::open_loop(1000.0);
        match w.mode {
            WorkloadMode::OpenLoop { interval, poisson, max_in_flight, queue_cap } => {
                assert_eq!(interval, SEC / 1000);
                assert!(!poisson);
                assert_eq!(max_in_flight, 64);
                assert_eq!(queue_cap, DEFAULT_QUEUE_CAP);
            }
            other => panic!("{other:?}"),
        }
        let rate = w.offered_rate().unwrap();
        assert!((rate - 1000.0).abs() < 1.0, "rate {rate}");
        assert!(matches!(
            WorkloadSpec::open_loop_poisson(500.0).mode,
            WorkloadMode::OpenLoop { poisson: true, .. }
        ));
    }

    #[test]
    fn knobs_compose() {
        let w = WorkloadSpec::open_loop(2000.0)
            .max_in_flight(16)
            .payload_bytes(32)
            .start_at(5)
            .stop_at(99)
            .resend_after(7);
        assert_eq!(w.in_flight_bound(), 16);
        assert_eq!(w.payload, PayloadSpec::Fixed(vec![0u8; 32]));
        assert_eq!((w.start_at, w.stop_at, w.resend_after), (5, 99, 7));
    }

    #[test]
    fn read_knobs_default_off_and_clamp() {
        let w = WorkloadSpec::closed_loop();
        assert_eq!(w.read_fraction, 0.0);
        assert_eq!(w.read_payload, PayloadSpec::Fixed(Vec::new()));
        let w = WorkloadSpec::open_loop(100.0)
            .read_fraction(0.9)
            .read_payload(vec![b'g', 1, b'k']);
        assert!((w.read_fraction - 0.9).abs() < 1e-9);
        assert_eq!(w.read_payload.bytes_for(3), vec![b'g', 1, b'k']);
        // Out-of-range fractions clamp rather than panic.
        assert_eq!(WorkloadSpec::closed_loop().read_fraction(7.0).read_fraction, 1.0);
        assert_eq!(WorkloadSpec::closed_loop().read_fraction(-1.0).read_fraction, 0.0);
        assert_eq!(WorkloadSpec::closed_loop().read_fraction(f64::NAN).read_fraction, 0.0);
    }

    #[test]
    fn queue_cap_knob() {
        let w = WorkloadSpec::open_loop(100.0).queue_cap(7);
        assert!(matches!(w.mode, WorkloadMode::OpenLoop { queue_cap: 7, .. }));
        // Clamped to ≥ 1 (a zero cap would drop every arrival).
        let w = WorkloadSpec::open_loop_poisson(100.0).queue_cap(0);
        assert!(matches!(w.mode, WorkloadMode::OpenLoop { queue_cap: 1, .. }));
        // No-op on closed loops.
        let w = WorkloadSpec::pipelined(4).queue_cap(9);
        assert_eq!(w.mode, WorkloadMode::ClosedLoop { window: 4 });
    }

    #[test]
    fn per_client_payloads() {
        fn gen(id: NodeId) -> Vec<u8> {
            vec![id as u8, 7]
        }
        let w = WorkloadSpec::closed_loop().payload_with(gen);
        assert_eq!(w.payload.bytes_for(3), vec![3, 7]);
        assert_eq!(w.payload.bytes_for(9), vec![9, 7]);
    }

    #[test]
    #[should_panic(expected = "open-loop rate must be positive")]
    fn zero_rate_rejected() {
        WorkloadSpec::open_loop(0.0);
    }
}
