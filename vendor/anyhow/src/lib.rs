//! A vendored, minimal stand-in for the `anyhow` crate so the build is
//! fully offline (no registry access). It implements exactly the subset
//! this repository uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. The coherence
//! tricks (a `From<E: std::error::Error>` blanket on a type that itself
//! does *not* implement `std::error::Error`, and the private `ext` trait
//! backing `Context`) mirror the real crate.

use std::error::Error as StdError;
use std::fmt;

/// A boxed-ish dynamic error with human-readable context, mirroring
/// `anyhow::Error`. Intentionally does NOT implement `std::error::Error`
/// (that is what makes the blanket `From` impl coherent).
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend a layer of context to the message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause chain, outermost first (subset of anyhow's API).
    pub fn root_cause(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_ref().map(|e| e.as_ref() as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::*;

    /// Private dispatch trait: "anything that can absorb context". The two
    /// impls below do not overlap because [`Error`] does not implement
    /// `std::error::Error`.
    pub trait IntoError {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::new(self).context(context)
        }
    }

    impl IntoError for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
        assert!(e.root_cause().is_some());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");

        // Context on an already-anyhow Result (the ext::IntoError impl
        // for Error itself).
        let r: Result<()> = Err(anyhow!("inner {}", 3));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 3");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
        let msg = String::from("plain");
        assert_eq!(anyhow!(msg).to_string(), "plain");
    }
}
