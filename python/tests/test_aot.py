"""AOT path: the lowered HLO text parses, is re-loadable, and executing it
through xla_client (the same XLA the Rust binary links) matches the oracle.
This closes the loop python→HLO→XLA without needing the Rust binary."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_artifacts_build(tmp_path):
    written = aot.build_artifacts(str(tmp_path))
    assert len(written) == len(aot.BATCH_SIZES)
    for path in written:
        text = open(path).read()
        assert "HloModule" in text
        # Text (not proto) interchange: ids must be re-parseable.
        assert len(text) > 200


def test_hlo_text_mentions_tuple_output(tmp_path):
    aot.build_artifacts(str(tmp_path))
    text = open(os.path.join(str(tmp_path), "apply_batch_b8.hlo.txt")).read()
    # return_tuple=True → root is a tuple of (state, digest).
    assert "tuple" in text


@pytest.mark.parametrize("b", aot.BATCH_SIZES)
def test_hlo_text_reparses_with_correct_signature(b):
    """The text artifact must re-parse through XLA's HLO text parser (the
    exact path the Rust runtime uses via HloModuleProto::from_text_file)
    and keep the (D,D) + (B,D) → tuple signature."""
    lowered = jax.jit(model.apply_batch).lower(*model.example_args(b))
    text = aot.to_hlo_text(lowered)
    module = xc._xla.hlo_module_from_text(text)
    # Parsed text round-trips and keeps the entry signature.
    dump = module.to_string()
    assert f"f32[{ref.D},{ref.D}]" in dump  # state parameter
    assert f"f32[{b},{ref.D}]" in dump  # command batch parameter
    assert f"f32[{b}]" in dump  # digest output leaf
    # And re-serializes to a proto (what client.compile consumes).
    assert len(module.as_serialized_hlo_module_proto()) > 0


@pytest.mark.parametrize("b", aot.BATCH_SIZES)
def test_jitted_model_matches_ref_at_artifact_shapes(b):
    """Numerical ground truth at exactly the AOT shapes: what the compiled
    artifact computes (jit path) must equal the oracle. Rust-side execution
    of the parsed text is covered by `cargo test` (statemachine::tensor)."""
    rng = np.random.default_rng(b)
    state = jnp.asarray(rng.standard_normal((ref.D, ref.D)), jnp.float32)
    cmds = jnp.asarray(rng.standard_normal((b, ref.D)), jnp.float32)
    got_s, got_d = jax.jit(model.apply_batch)(state, cmds)
    want_s, want_d = ref.apply_batch_ref(state, cmds)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-5)
