"""L2 correctness: the full apply_batch step vs the oracle, plus the
deterministic cross-language contracts the Rust side relies on."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype=jnp.float32)


@pytest.mark.parametrize("b", [1, 8, 32])
def test_apply_batch_matches_ref(b):
    state = rand((ref.D, ref.D), seed=1)
    cmds = rand((b, ref.D), seed=2)
    got_s, got_d = model.apply_batch(state, cmds)
    want_s, want_d = ref.apply_batch_ref(state, cmds)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-5)


def test_zero_commands_decay_only():
    state = rand((ref.D, ref.D), seed=3)
    cmds = jnp.zeros((8, ref.D), jnp.float32)
    new_state, digest = model.apply_batch(state, cmds)
    np.testing.assert_allclose(new_state, ref.DECAY * state, rtol=1e-6)
    np.testing.assert_array_equal(digest, np.zeros(8))


def test_zero_padding_preserves_digests():
    # The Rust runtime pads partial batches with zero commands; the real
    # commands' digests must be unaffected and the padded rows contribute
    # nothing to the state beyond what the real rows do.
    state = rand((ref.D, ref.D), seed=4)
    cmds = rand((5, ref.D), seed=5)
    padded = jnp.concatenate([cmds, jnp.zeros((3, ref.D), jnp.float32)])
    s_real, d_real = model.apply_batch(state, cmds)
    s_pad, d_pad = model.apply_batch(state, padded)
    np.testing.assert_allclose(s_pad, s_real, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(d_pad[:5], d_real, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(d_pad[5:], np.zeros(3))


def test_determinism_across_jit_replays():
    # Replicas stay in sync because the compiled step is deterministic.
    state = rand((ref.D, ref.D), seed=6)
    cmds = rand((8, ref.D), seed=7)
    s1, d1 = model.apply_batch(state, cmds)
    s2, d2 = model.apply_batch(state, cmds)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(d1, d2)


def test_mixing_matrix_matches_rust_pattern():
    # Must equal tensor.rs::mixing_matrix exactly (integer pattern / 4).
    w = np.asarray(ref.mixing_matrix())
    for i in range(ref.D):
        for j in range(ref.D):
            assert w[i, j] == ((i * 31 + j * 17) % 7 - 3) / 4.0


def test_example_args_shapes():
    s, c = model.example_args(8)
    assert s.shape == (ref.D, ref.D)
    assert c.shape == (8, ref.D)
    assert str(s.dtype) == "float32"
