"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled compute path:
``batch_apply.mix`` (tiled Pallas matmul, interpret mode) must match
``ref.mix_ref`` to float tolerance across shapes and value ranges. We
sweep shapes/values both with explicit parametrization and with a
hypothesis-style randomized sweep driven by numpy RNG (the environment is
offline; the sweep covers the same space a hypothesis strategy would).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import batch_apply, ref

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment without hypothesis
    HAVE_HYPOTHESIS = False


def rand(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype=dtype)


@pytest.mark.parametrize("b", [1, 2, 8, 32])
@pytest.mark.parametrize("d", [16, 32])
def test_mix_matches_ref(b, d):
    w = ref.mixing_matrix(d)
    cmds = rand((b, d), seed=b * 100 + d)
    got = batch_apply.mix(cmds, w)
    want = ref.mix_ref(cmds, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "b,k,d",
    [
        (1, 16, 16),
        (4, 32, 16),
        (128, 128, 128),  # exactly one 128-tile
        (256, 128, 256),  # multi-tile grid
        (96, 48, 96),     # non-128 divisors
        (3, 5, 7),        # awkward primes (tile = full dim)
    ],
)
def test_mix_general_shapes(b, k, d):
    w = rand((k, d), seed=k * 7 + d)
    cmds = rand((b, k), seed=b)
    got = batch_apply.mix(cmds, w)
    want = jnp.dot(cmds, w, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mix_zero_input():
    w = ref.mixing_matrix()
    z = jnp.zeros((8, ref.D), jnp.float32)
    np.testing.assert_array_equal(batch_apply.mix(z, w), np.zeros((8, ref.D)))


def test_mix_large_values():
    # f32 head-room: values up to 1e3 with D=16 accumulation stay exact
    # enough for 1e-3 relative tolerance.
    w = ref.mixing_matrix()
    cmds = rand((8, ref.D), seed=3, scale=1e3)
    got = batch_apply.mix(cmds, w)
    want = ref.mix_ref(cmds, w)
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_tile_picker():
    assert batch_apply._pick_tile(256) == 128
    assert batch_apply._pick_tile(96) == 96
    assert batch_apply._pick_tile(7) == 7
    assert batch_apply._pick_tile(130) == 65


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        b=st.integers(1, 64),
        d=st.sampled_from([8, 16, 24, 32]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([1e-3, 1.0, 10.0]),
    )
    def test_mix_hypothesis_sweep(b, d, seed, scale):
        w = ref.mixing_matrix(d)
        cmds = rand((b, d), seed=seed, scale=scale)
        got = batch_apply.mix(cmds, w)
        want = ref.mix_ref(cmds, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5 * scale)

else:

    @pytest.mark.parametrize("trial", range(30))
    def test_mix_randomized_sweep(trial):
        rng = np.random.default_rng(trial)
        b = int(rng.integers(1, 65))
        d = int(rng.choice([8, 16, 24, 32]))
        scale = float(rng.choice([1e-3, 1.0, 10.0]))
        w = ref.mixing_matrix(d)
        cmds = rand((b, d), seed=trial + 1000, scale=scale)
        got = batch_apply.mix(cmds, w)
        want = ref.mix_ref(cmds, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5 * scale)
