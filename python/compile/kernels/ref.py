"""Pure-jnp reference (oracle) for the tensor state machine step.

This is the ground truth the L1 Pallas kernel and the L2 model are checked
against by pytest (and, transitively, what the Rust-side
``statemachine::tensor::reference_step`` mirrors).

Semantics (one replicated-state-machine batch step):

    M  = C @ W                  # command mixing (the MXU matmul)
    S' = DECAY * S + M.T @ C    # rank-B state update
    d  = rowsum(M * C)          # per-command digest (client reply)

``W`` is a fixed integer-pattern matrix, exactly representable in f32 on
both the Python and Rust sides: ``W[i, j] = ((i*31 + j*17) % 7 - 3) / 4``.
"""

import jax.numpy as jnp
import numpy as np

# State dimension; must match rust/src/statemachine/tensor.rs::D.
D = 16
# Per-batch state decay; must match tensor.rs::DECAY.
DECAY = 0.5


def mixing_matrix(d: int = D) -> jnp.ndarray:
    """The fixed mixing matrix W (identical across Python and Rust)."""
    i = np.arange(d)[:, None]
    j = np.arange(d)[None, :]
    w = ((i * 31 + j * 17) % 7 - 3) / 4.0
    return jnp.asarray(w, dtype=jnp.float32)


def mix_ref(cmds: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference for the L1 kernel: M = C @ W."""
    return jnp.dot(cmds, w, preferred_element_type=jnp.float32)


def apply_batch_ref(state: jnp.ndarray, cmds: jnp.ndarray):
    """Reference for the full L2 step: (S', d)."""
    w = mixing_matrix(state.shape[0])
    m = mix_ref(cmds, w)
    new_state = DECAY * state + jnp.dot(m.T, cmds, preferred_element_type=jnp.float32)
    digest = jnp.sum(m * cmds, axis=1)
    return new_state, digest
