"""L1 — the Pallas kernel: tiled command-mixing matmul ``M = C @ W``.

TPU-shaped design (DESIGN.md §Hardware-Adaptation): the kernel tiles the
``(B, D) x (D, D)`` matmul over a grid of ``(B/TB, D/TD)`` output blocks.
Each grid step stages one ``(TB, K)`` command tile and one ``(K, TD)``
weight tile through VMEM (expressed with ``BlockSpec``) and issues an
MXU-shaped ``dot`` with f32 accumulation. For the small shapes the
replicated state machine uses (D = 16, B ≤ 32) a single tile covers the
whole problem, but the grid code is written generally and is exercised at
larger shapes by the hypothesis tests.

On CPU we run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. The interpret path lowers
to plain HLO, which is what ``aot.py`` ships to the Rust runtime.

VMEM accounting (per grid step, f32): TB*K + K*TD + TB*TD floats. With the
default TB = TD = K ≤ 128 this is ≤ 3 * 128 * 128 * 4 B = 192 KiB, far
under the ~16 MiB VMEM budget; double-buffering by the pipeline emitter
doubles it, still comfortable. MXU utilization estimate: the inner dot is
a dense (TB, K) x (K, TD) contraction — systolic-array shaped with no
wasted lanes when TB, TD are multiples of 128 (padded otherwise).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(c_ref, w_ref, o_ref):
    """One output tile: o = c @ w with f32 accumulation."""
    o_ref[...] = jnp.dot(
        c_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(n: int, target: int = 128) -> int:
    """Largest divisor of ``n`` that is ≤ target (VMEM/MXU tile size)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def mix(cmds: jnp.ndarray, w: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """``M = cmds @ w`` as a tiled Pallas kernel.

    cmds: (B, K) f32; w: (K, D) f32 → (B, D) f32.
    """
    b, k = cmds.shape
    k2, d = w.shape
    assert k == k2, f"contraction mismatch: {cmds.shape} @ {w.shape}"
    tb = _pick_tile(b)
    td = _pick_tile(d)
    grid = (b // tb, d // td)
    return pl.pallas_call(
        _mix_kernel,
        grid=grid,
        # HBM→VMEM schedule: block (i, j) reads command rows i*TB.. and
        # weight columns j*TD..; the full K dimension is staged per block
        # (K is small for this model; tile K too if it ever grows).
        in_specs=[
            pl.BlockSpec((tb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, td), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tb, td), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(cmds, w)
