"""L2 — the JAX model: one replicated-state-machine batch step.

``apply_batch(state, cmds)`` is the computation every replica executes for
a batch of chosen commands. The hot spot — the command-mixing matmul — is
the L1 Pallas kernel (``kernels.batch_apply.mix``); the rank-B state
update and the per-command digest are plain jnp, fused by XLA around the
kernel. ``aot.py`` lowers this function once per compiled batch size and
ships HLO text to the Rust runtime; Python never runs at request time.
"""

import jax
import jax.numpy as jnp

from .kernels import batch_apply
from .kernels.ref import DECAY, D, mixing_matrix


def apply_batch(state: jnp.ndarray, cmds: jnp.ndarray):
    """One batch step: returns ``(new_state, digests)``.

    state: (D, D) f32 — the replicated state.
    cmds:  (B, D) f32 — the batch of decoded commands.
    """
    w = mixing_matrix(state.shape[0])
    m = batch_apply.mix(cmds, w)  # L1 Pallas kernel
    new_state = DECAY * state + jnp.dot(m.T, cmds, preferred_element_type=jnp.float32)
    digest = jnp.sum(m * cmds, axis=1)
    return new_state, digest


def example_args(batch: int, d: int = D):
    """Shape specs for AOT lowering at a given batch size."""
    return (
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((batch, d), jnp.float32),
    )
