"""AOT lowering: JAX model → HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

One artifact per compiled batch size (``apply_batch_b{B}.hlo.txt``); the
Rust ``TensorStateMachine`` pads request batches up to the nearest size.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (via
``make artifacts``). Python runs ONLY here, never on the request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Compiled batch sizes; must match tensor.rs::BATCH_SIZES.
BATCH_SIZES = [1, 8, 32]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path).

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides big constants as ``constant({...})``, which the text parser then
    reads back as ZEROS — the model's mixing matrix would silently vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def build_artifacts(out_dir: str) -> list:
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for b in BATCH_SIZES:
        lowered = jax.jit(model.apply_batch).lower(*model.example_args(b))
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"apply_batch_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_artifacts(args.out_dir)


if __name__ == "__main__":
    main()
