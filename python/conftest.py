"""Make `python/` importable regardless of pytest's invocation directory
(`pytest python/tests/` from the repo root or `pytest tests/` from
`python/` both work)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
