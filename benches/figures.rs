//! `cargo bench --bench figures` — regenerate every table and figure of
//! the paper's evaluation (§8) on the deterministic simulator and print
//! the series/rows, plus wall-clock cost of each driver.
//!
//! (Plain `harness = false` binary: the build is offline/self-contained,
//! so the harness is in-tree rather than criterion. Each experiment is
//! deterministic given `--seed`.)

use matchmaker::harness::experiments as exp;
use std::time::Instant;

fn timed(name: &str, f: impl FnOnce() -> String) {
    let t0 = Instant::now();
    let text = f();
    let dt = t0.elapsed();
    println!("{text}");
    println!("[bench] {name} regenerated in {:.2} s (wall)\n", dt.as_secs_f64());
}

fn main() {
    let seed: u64 = std::env::args()
        .skip_while(|a| a != "--seed")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let only: Option<String> = std::env::args().skip_while(|a| a != "--only").nth(1);
    let want = |id: &str| only.as_deref().map_or(true, |o| o.eq_ignore_ascii_case(id));

    println!("# Matchmaker Paxos — paper evaluation reproduction (seed {seed})\n");

    if want("f9") {
        timed("Figure 9 + Table 1", || {
            let (fig, tab) = exp::figure9(seed);
            format!("{}{}", fig.render(), tab.render())
        });
    }
    if want("f10") {
        timed("Figure 10 (+ stats)", || {
            let (fig, tab) = exp::figure10(seed);
            format!("{}{}", fig.render(), tab.render())
        });
    }
    if want("f11") {
        timed("Figure 11 (f=2)", || {
            let (fig, tab) = exp::figure11(seed);
            format!("{}{}", fig.render(), tab.render())
        });
    }
    if want("f12") {
        timed("Figures 12/13 (violin quartiles)", || exp::figure12_13(seed).render());
    }
    if want("f14") {
        timed("Figure 14 (thrifty curves)", || exp::figure14(seed).render());
    }
    if want("f15") {
        timed("Figure 15 (non-thrifty)", || exp::figure15(seed).0.render());
    }
    if want("f16") {
        timed("Figure 16 (100 clients)", || exp::figure16(seed).render());
    }
    if want("f17") {
        timed("Figure 17 (WAN ablation)", || exp::figure17(seed).render());
    }
    if want("f18") {
        timed("Figure 18 (leader failure)", || exp::figure18(seed).render());
    }
    if want("f19") {
        timed("Figure 19 (horizontal steady)", || exp::figure19(seed).render());
    }
    if want("f20") {
        timed("Figure 20 (triple failure)", || exp::figure20(seed).render());
    }
    if want("f21") {
        timed("Figure 21 + Table 2 (matchmaker reconfig)", || {
            let (fig, tab) = exp::figure21(seed);
            format!("{}{}", fig.render(), tab.render())
        });
    }
    if want("x2") {
        timed("X2 (Matchmaker Fast Paxos)", || exp::fast_paxos_experiment(seed).render());
    }
    if want("x3") {
        timed("X3 (Phase 2 batching, tensor path)", || exp::batching_figure(seed).render());
    }
    if want("x4") {
        timed("X4 (open-loop offered-load sweep)", || exp::open_loop_figure(seed).render());
    }
    if want("x5") {
        timed("X5 (state retention)", || exp::retention_figure(seed).render());
    }
    if want("x6") {
        timed("X6 (sharded multi-group scale-out)", || exp::sharding_figure(seed).render());
    }
    if want("x7") {
        timed("X7 (leased linearizable reads)", || exp::read_scaling_figure(seed).render());
    }
}
