//! `cargo bench --bench micro` — microbenchmarks of the hot paths, with a
//! small in-tree measurement harness (median-of-runs; the build is offline
//! so no criterion). These feed EXPERIMENTS.md §Perf.
//!
//! Benchmarks:
//! * acceptor Phase2A handling        (per-message cost on the hot path)
//! * leader propose→chosen pipeline   (per-command bookkeeping cost)
//! * simulator event throughput      (events/s — bounds how fast the §8
//!   timelines regenerate)
//! * wire codec encode/decode
//! * end-to-end simulated cluster throughput (commands/s of sim time per
//!   second of wall time)
//! * tensor state machine batch apply (always runs: reference backend by
//!   default, PJRT with `--features pjrt` + `make artifacts`)
//! * Phase 2 batching: simulated throughput at batch_size 1/8/32 on the
//!   tensor path with a finite per-message egress cost

use matchmaker::codec::Wire;
use matchmaker::config::{Configuration, OptFlags};
use matchmaker::harness::{secs, Cluster};
use matchmaker::msg::{Command, Envelope, Msg, Value};
use matchmaker::node::{Effects, Node};
use matchmaker::roles::Acceptor;
use matchmaker::round::Round;
use matchmaker::workload::WorkloadSpec;
use std::time::Instant;

/// Run `f(n)` with increasing n until it takes ≥0.2 s, then report
/// ns/iter from the best of 3 runs.
fn bench(name: &str, mut f: impl FnMut(u64)) {
    let mut n = 1000u64;
    loop {
        let t0 = Instant::now();
        f(n);
        let dt = t0.elapsed();
        if dt.as_secs_f64() >= 0.2 || n >= 1 << 28 {
            let mut best = dt.as_secs_f64();
            for _ in 0..2 {
                let t0 = Instant::now();
                f(n);
                best = best.min(t0.elapsed().as_secs_f64());
            }
            let per = best / n as f64 * 1e9;
            let rate = n as f64 / best;
            println!("{name:<42} {per:>10.1} ns/iter   {rate:>12.0} /s");
            return;
        }
        n *= 4;
    }
}

fn main() {
    println!("# micro benchmarks (best of 3)\n");

    // --- acceptor Phase2A hot path ---
    bench("acceptor: Phase2A vote", |n| {
        let mut acc = Acceptor::new(1);
        let round = Round::first(1, 0);
        let mut fx = Effects::new();
        for slot in 0..n {
            acc.on_msg(0, 0, Msg::Phase2A { round, slot, value: Value::Noop }, &mut fx);
            fx.msgs.clear();
        }
        std::hint::black_box(&acc.votes);
    });

    // --- acceptor bulk Phase1 over a populated log ---
    bench("acceptor: Phase1A over 1k voted slots", |n| {
        let mut acc = Acceptor::new(1);
        let r0 = Round::first(1, 0);
        let mut fx = Effects::new();
        for slot in 0..1000 {
            acc.on_msg(0, 0, Msg::Phase2A { round: r0, slot, value: Value::Noop }, &mut fx);
        }
        fx.msgs.clear();
        for i in 0..n {
            let round = Round { epoch: 2 + i, proposer: 0, seq: 0 };
            acc.on_msg(0, 0, Msg::Phase1A { round, from_slot: 0 }, &mut fx);
            fx.msgs.clear();
        }
    });

    // --- codec ---
    let env = Envelope {
        from: 3,
        to: 9,
        msg: Msg::Phase2A {
            round: Round::first(2, 1),
            slot: 77,
            value: Value::Cmd(Command { client: 10, seq: 5, payload: vec![0u8; 16] }),
        },
    };
    let bytes = env.encode();
    bench("codec: encode Phase2A envelope", |n| {
        for _ in 0..n {
            std::hint::black_box(env.encode());
        }
    });
    bench("codec: decode Phase2A envelope", |n| {
        for _ in 0..n {
            std::hint::black_box(Envelope::decode(&bytes).unwrap());
        }
    });
    // Hot-path allocation satellite: frame-encode into a reused scratch
    // buffer vs a fresh allocation per message (the TCP writer path).
    bench("codec: frame-encode, alloc per message", |n| {
        for _ in 0..n {
            std::hint::black_box(matchmaker::net::encode_frame(&env));
        }
    });
    let mut scratch = matchmaker::codec::Enc::new();
    bench("codec: frame-encode, reused scratch", |n| {
        for _ in 0..n {
            matchmaker::net::encode_frame_into(&env, &mut scratch);
            std::hint::black_box(scratch.buf.len());
        }
    });

    // Hot-path allocation satellite: Chosen fan-out to 3 replicas via a
    // cloned template vs broadcast_move (one Value clone saved per
    // chosen slot — visible with batch values).
    let batch = Value::Batch(
        (0..32)
            .map(|i| Command { client: i, seq: 1, payload: vec![0u8; 16] })
            .collect(),
    );
    let replicas = [10u32, 11, 12];
    bench("effects: broadcast cloned template (batch32)", |n| {
        let mut fx = Effects::new();
        for slot in 0..n {
            let msg = Msg::Chosen { slot, value: batch.clone() };
            fx.broadcast(&replicas, &msg);
            fx.msgs.clear();
        }
        std::hint::black_box(&fx.msgs);
    });
    bench("effects: broadcast_move (batch32)", |n| {
        let mut fx = Effects::new();
        for slot in 0..n {
            fx.broadcast_move(&replicas, Msg::Chosen { slot, value: batch.clone() });
            fx.msgs.clear();
        }
        std::hint::black_box(&fx.msgs);
    });

    // --- storage: the per-ack durability cost an acceptor pays before
    // answering Phase 1/2 (DESIGN.md §Durability). MemStorage bounds the
    // trait overhead; the WAL rows split framing+write from the fsync
    // itself, which is the number that sets the durable-mode ack floor.
    let vote = matchmaker::storage::WalRecord::Vote {
        slot: 42,
        vr: Round::first(1, 0),
        vv: Value::Cmd(Command { client: 10, seq: 5, payload: vec![0u8; 16] }),
    };
    bench("storage: MemStorage append (vote)", |n| {
        use matchmaker::storage::{MemStorage, Storage};
        let mut st = MemStorage::default();
        for _ in 0..n {
            st.append(&vote).unwrap();
        }
        std::hint::black_box(&st);
    });
    for &fsync in &[false, true] {
        let name = if fsync {
            "storage: WAL append + fsync (vote)"
        } else {
            "storage: WAL append, no fsync (vote)"
        };
        bench(name, |n| {
            use matchmaker::storage::wal::{WalOptions, WalStorage};
            use matchmaker::storage::{scratch_dir, Storage};
            let dir = scratch_dir("bench-wal");
            let opts = WalOptions { fsync, ..WalOptions::default() };
            let mut st = WalStorage::open(&dir, opts).unwrap();
            for _ in 0..n {
                st.append(&vote).unwrap();
            }
            std::hint::black_box(&st);
            drop(st);
            let _ = std::fs::remove_dir_all(&dir);
        });
    }

    // --- simulator event throughput, end-to-end cluster ---
    bench("sim: end-to-end command (8 clients)", |n| {
        // One simulated second ≈ 14.6k commands with 8 clients; scale the
        // simulated horizon so ~n commands complete.
        let sim_secs = (n / 14_000).max(1);
        let mut cluster = Cluster::builder().clients(8).seed(42).build();
        cluster.sim.run_until(secs(sim_secs));
        std::hint::black_box(cluster.samples().len());
    });

    bench("sim: delivered message", |n| {
        let sim_secs = (n / 230_000).max(1);
        let mut cluster = Cluster::builder().clients(8).seed(42).build();
        cluster.sim.run_until(secs(sim_secs));
        std::hint::black_box(cluster.sim.delivered);
    });

    // --- leader pipeline within a pumped cluster (no network jitter) ---
    bench("cluster: reconfiguration (full lifecycle)", |n| {
        let mut cluster = Cluster::builder().clients(1).seed(42).build();
        let leader = cluster.initial_leader();
        cluster.sim.run_until(secs(1) / 10);
        for i in 0..n {
            let cfg = Configuration::majority(i + 1, cluster.random_config(i + 1).acceptors);
            cluster.sim.schedule(cluster.sim.now() + 1, move |s| {
                s.with_node::<matchmaker::roles::Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
            let t = cluster.sim.now() + 2_000_000; // +2 ms per reconfig
            cluster.sim.run_until(t);
        }
    });

    // --- tensor state machine batch apply (three-layer hot path;
    // reference backend by default, PJRT with `--features pjrt` +
    // `make artifacts`) ---
    let mut sm = matchmaker::statemachine::TensorStateMachine::load().unwrap();
    let backend = sm.backend_name();
    let cmds: Vec<Vec<f32>> = (0..32)
        .map(|i| (0..16).map(|j| ((i * 16 + j) % 11) as f32 / 4.0).collect())
        .collect();
    bench(&format!("tensor SM: batch-32 apply ({backend})"), |n| {
        for _ in 0..n {
            std::hint::black_box(sm.apply_batch(&cmds).unwrap());
        }
    });
    let one = vec![cmds[0].clone()];
    bench(&format!("tensor SM: batch-1 apply ({backend})"), |n| {
        for _ in 0..n {
            std::hint::black_box(sm.apply_batch(&one).unwrap());
        }
    });

    // --- Phase 2 batching end to end: simulated cluster throughput on
    // the tensor path with a finite per-message egress cost (the ISSUE-1
    // acceptance measurement; see harness::experiments::batching_figure
    // for the full X3 report) ---
    println!("\n# Phase 2 batching (32 clients, 20 us/msg egress, 2 sim-seconds)\n");
    let mut base = f64::NAN;
    for &bs in &[1usize, 8, 32] {
        let run =
            matchmaker::harness::experiments::run_batching_throughput(42, bs, 32, secs(2));
        if bs == 1 {
            base = run.throughput;
        }
        println!(
            "batch_size={bs:<3} {:>10.0} cmds/s (sim)   median {:>7.3} ms   {:>5.1}x",
            run.throughput,
            run.median_ms,
            run.throughput / base
        );
    }

    // --- leased reads: the X7 90/10 mix with reads through the log vs
    // served by replicas under leases, at equal offered load (see
    // harness::experiments::read_scaling_figure for the full report) ---
    println!("\n# leased reads (90/10 mix, 8 clients x 2000/s, 40 us/msg egress, 3 sim-seconds)\n");
    let mut base_ops = f64::NAN;
    for (label, variant) in [
        ("all through Phase 2 (baseline)", matchmaker::harness::experiments::ReadVariant::Baseline),
        ("leased replica reads", matchmaker::harness::experiments::ReadVariant::Leased),
    ] {
        let run = matchmaker::harness::experiments::run_read_scaling(42, variant, secs(3));
        if base_ops.is_nan() {
            base_ops = run.summary.completed_per_sec;
        }
        println!(
            "{label:<40} {:>10.0} ops/s (sim)   p50 {:>7.3} ms   {:>5.1}x",
            run.summary.completed_per_sec,
            run.summary.latency.median,
            run.summary.completed_per_sec / base_ops
        );
    }

    // --- workload modes: closed-loop vs open-loop-pipelined chosen
    // commands/sec at equal client count (the ISSUE-2 pipelining win;
    // see harness::experiments::open_loop_figure for the X4 sweep) ---
    println!("\n# workload modes (4 clients, lan, 2 sim-seconds, reconfig at 1 s)\n");
    let mut closed_rate = f64::NAN;
    let workloads: [(&str, WorkloadSpec); 3] = [
        ("closed-loop (window 1)", WorkloadSpec::closed_loop()),
        ("pipelined closed-loop (window 16)", WorkloadSpec::pipelined(16)),
        (
            "open-loop pipelined (6000/s/client, in-flight 16)",
            WorkloadSpec::open_loop(6000.0).max_in_flight(16),
        ),
    ];
    for (label, spec) in workloads {
        let mut cluster = Cluster::builder().clients(4).workload(spec).seed(42).build();
        let leader = cluster.initial_leader();
        let cfg = cluster.random_config(1);
        cluster.sim.schedule(secs(1), move |s| {
            s.with_node::<matchmaker::roles::Leader, _>(leader, |l, now, fx| {
                l.reconfigure(cfg.clone(), now, fx)
            });
        });
        cluster.sim.run_until(secs(2));
        cluster.assert_safe();
        let rate = cluster.samples().len() as f64 / 2.0;
        if closed_rate.is_nan() {
            closed_rate = rate;
        }
        println!(
            "{label:<50} {rate:>10.0} cmds/s (sim)   {:>5.1}x",
            rate / closed_rate
        );
    }
}
