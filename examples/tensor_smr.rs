//! End-to-end three-layer driver (the repo's full-stack proof point):
//!
//!   clients → Rust Matchmaker MultiPaxos (L3) → replicas execute every
//!   chosen command through the AOT-compiled JAX/Pallas program (L2+L1)
//!   loaded via PJRT — Python is never on the request path.
//!
//! A real small workload: 8 pipelined clients (4 requests in flight
//! each, `WorkloadSpec::pipelined(4)`) stream 16-float tensor commands
//! for 6 simulated seconds, batched 8-per-slot by the leader (Phase 2
//! batching); at 2 s the acceptors are live-reconfigured; at 4 s the
//! matchmakers are. We report latency/throughput and verify all three
//! tensor-backed replicas converge to bit-identical state.
//!
//! Uses the compiled PJRT artifacts with `--features pjrt` +
//! `make artifacts`, else the pure-Rust reference backend. Run:
//!
//! ```sh
//! cargo run --release --example tensor_smr
//! ```

use matchmaker::config::OptFlags;
use matchmaker::harness::experiments::tensor_lane_payload;
use matchmaker::harness::{secs, Cluster};
use matchmaker::metrics::{interval_summary, timeline};
use matchmaker::roles::{Leader, Replica};
use matchmaker::statemachine::{StateMachine, TensorStateMachine};
use matchmaker::workload::WorkloadSpec;
use matchmaker::{Configuration, MS, SEC, US};

fn main() {
    // Pipelined closed-loop clients, each streaming a distinct 16-lane
    // tensor command (keyed off its node id); stop issuing at 5.5 s so
    // the tail drains and every replica reaches the same log prefix
    // before we compare states.
    let workload = WorkloadSpec::pipelined(4)
        .payload_with(tensor_lane_payload)
        .stop_at(secs(5) + 500 * MS);
    let mut cluster = Cluster::builder()
        .f(1)
        .clients(8)
        .workload(workload)
        .opts(OptFlags::default().with_batching(8, 500 * US))
        .seed(2026)
        .build();
    let leader = cluster.initial_leader();

    // Swap the replicas' no-op state machines for tensor SMs.
    let replicas = cluster.layout.replicas.clone();
    for &r in &replicas {
        let sm = TensorStateMachine::load().expect("load tensor state machine");
        println!("replica {r}: tensor backend = {}", sm.backend_name());
        let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
        rep.sm = Box::new(sm);
    }

    // Live reconfigurations mid-stream: acceptors at 2 s, matchmakers at 4 s.
    let new_cfg = Configuration::majority(1, cluster.layout.acceptor_pool[3..6].to_vec());
    cluster.sim.schedule(secs(2), move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(new_cfg.clone(), now, fx));
    });
    let new_mms = cluster.layout.matchmaker_pool[3..6].to_vec();
    cluster.sim.schedule(secs(4), move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| {
            l.reconfigure_matchmakers(new_mms.clone(), now, fx)
        });
    });

    cluster.sim.run_until(secs(6));
    cluster.assert_safe();

    let samples = cluster.samples();
    let tl = timeline(&samples, secs(6), SEC, 500 * MS);
    println!("tensor SMR: {} commands executed through XLA in 6 simulated seconds\n", samples.len());
    println!("t_sec\tthroughput\tmedian_ms");
    for i in 0..tl.t.len() {
        let marker = match tl.t[i] {
            t if (2.0..2.5).contains(&t) => "  <- acceptor reconfig",
            t if (4.0..4.5).contains(&t) => "  <- matchmaker reconfig",
            _ => "",
        };
        println!("{:>5.1}\t{:>10.0}\t{:>9.3}{}", tl.t[i], tl.throughput[i], tl.median_ms[i], marker);
    }
    if let Some(s) = interval_summary(&samples, 0, secs(6)) {
        println!(
            "\noverall: median latency {:.3} ms, p95 {:.3} ms, median throughput {:.0} cmds/s",
            s.latency.median, s.latency.p95, s.throughput.median
        );
    }

    // All replicas must hold bit-identical tensor state (the digest is an
    // FNV over the raw f32 state — exact equality required).
    let digests: Vec<(u64, u64)> = replicas
        .iter()
        .map(|&r| {
            let rep = cluster.sim.node_mut::<Replica>(r).unwrap();
            (rep.sm.digest(), rep.executed)
        })
        .collect();
    println!("\nreplica states: {digests:?}");
    assert!(
        digests.windows(2).all(|w| w[0].0 == w[1].0),
        "replica tensor states diverged!"
    );
    assert!(digests[0].1 > 100, "replicas executed too few commands");
    println!("all {} replicas converged to identical XLA state — tensor_smr OK", replicas.len());
}
