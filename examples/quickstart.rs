//! Quickstart: stand up a Matchmaker MultiPaxos cluster in the simulator,
//! run client commands, perform one live reconfiguration, and print what
//! happened. Run with:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use matchmaker::config::Configuration;
use matchmaker::harness::{secs, Cluster};
use matchmaker::metrics::interval_summary;
use matchmaker::node::Announce;
use matchmaker::roles::Leader;
use matchmaker::workload::WorkloadSpec;

fn main() {
    // f = 1: 2 proposers, 6-acceptor pool (3 active), 6 matchmakers
    // (3 active), 3 replicas — the paper's deployment — plus 4
    // closed-loop clients (the §8.1 workload; swap the spec for
    // `WorkloadSpec::open_loop(...)` or `::pipelined(k)` to load the
    // same cluster differently).
    let mut cluster = Cluster::builder()
        .f(1)
        .clients(4)
        .workload(WorkloadSpec::closed_loop())
        .seed(42)
        .build();
    let leader = cluster.initial_leader();
    println!(
        "cluster: f=1, leader = node {leader}, initial acceptors = {:?}",
        cluster.layout.initial_config().acceptors
    );

    // At t = 1 s, reconfigure to a brand-new acceptor set — no downtime.
    let new_acceptors = cluster.layout.acceptor_pool[3..6].to_vec();
    let new_cfg = Configuration::majority(1, new_acceptors.clone());
    cluster.sim.schedule(secs(1), move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(new_cfg.clone(), now, fx));
    });

    cluster.sim.run_until(secs(2));
    cluster.assert_safe();

    let samples = cluster.samples();
    println!("\n{} commands completed in 2 simulated seconds", samples.len());
    for (label, from, to) in
        [("before reconfig", 0, secs(1)), ("after reconfig", secs(1), secs(2))]
    {
        if let Some(s) = interval_summary(&samples, from, to) {
            println!(
                "  {label:>15}: median latency {:.3} ms, throughput ~{:.0} cmds/s",
                s.latency.median, s.throughput.median
            );
        }
    }

    // Show the reconfiguration lifecycle from the announcement stream.
    println!("\nreconfiguration lifecycle (→ acceptors {new_acceptors:?}):");
    for (t, _, a) in &cluster.sim.announces {
        match a {
            Announce::ConfigActive { round, config_id: 1, .. } => {
                println!("  t={:.4}s config 1 ACTIVE in round {round}", *t as f64 / 1e9)
            }
            Announce::ConfigRetired { round, .. } if round.seq == 1 => println!(
                "  t={:.4}s configs below round {round} RETIRED (old acceptors may shut down)",
                *t as f64 / 1e9
            ),
            _ => {}
        }
    }
    println!("\nquickstart OK");
}
