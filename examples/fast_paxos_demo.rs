//! Matchmaker Fast Paxos (§7): consensus in one round trip with only
//! `f+1` acceptors — the first protocol to meet the Fast Paxos quorum-size
//! lower bound (classic Fast Paxos needs > f+1-sized quorums).
//!
//! Runs two scenarios: a conflict-free fast round (value chosen in one
//! client→acceptor→coordinator round trip) and a conflicted round (two
//! clients race; coordinated recovery still chooses exactly one value).
//!
//! ```sh
//! cargo run --release --example fast_paxos_demo
//! ```

use matchmaker::config::Configuration;
use matchmaker::harness::msec;
use matchmaker::msg::{Command, Msg, Value};
use matchmaker::node::Announce;
use matchmaker::quorum::QuorumSpec;
use matchmaker::roles::{Acceptor, FastProposer, Matchmaker};
use matchmaker::sim::lan_sim;

fn value(tag: u8) -> Value {
    Value::Cmd(Command { client: 100 + tag as u32, seq: 1, payload: vec![tag] })
}

fn run_scenario(conflict: bool) {
    let mut sim = lan_sim(if conflict { 2 } else { 1 });
    // 3 matchmakers (ids 1-3), f+1 = 2 fast acceptors (ids 10, 11),
    // coordinator id 0. Singleton P1 quorums, one unanimous P2 quorum.
    for m in 1..=3 {
        sim.add_node(m, Box::new(Matchmaker::new(m)));
    }
    sim.add_node(10, Box::new(Acceptor::new_fast(10)));
    sim.add_node(11, Box::new(Acceptor::new_fast(11)));
    let cfg = Configuration { id: 0, acceptors: vec![10, 11], quorum: QuorumSpec::FastUnanimous };
    sim.add_node(0, Box::new(FastProposer::new(0, 1, vec![1, 2, 3], cfg)));

    // Open the fast round (matchmaking + Phase 1, no client value needed).
    sim.with_node::<FastProposer, _>(0, |p, now, fx| p.open_round(now, fx));
    sim.run_until(msec(5));
    let round = sim
        .with_node::<FastProposer, _>(0, |p, _, _| p.fast_round())
        .flatten()
        .expect("fast round open");

    // Clients propose DIRECTLY to the acceptors — no leader on the path.
    let (v1, v2) = if conflict { (value(1), value(2)) } else { (value(7), value(7)) };
    sim.schedule(msec(6), move |s| {
        s.with_node::<FastProposer, _>(0, move |_, _, fx| {
            fx.send(10, Msg::FastPropose { round, value: v1.clone() });
            fx.send(11, Msg::FastPropose { round, value: v2.clone() });
        });
    });
    sim.run_until(msec(100));
    sim.check_chosen_safety().expect("safety");

    let chosen = sim
        .with_node::<FastProposer, _>(0, |p, _, _| p.chosen.clone())
        .flatten()
        .expect("a value must be chosen");
    let fast = sim
        .announces
        .iter()
        .any(|(_, _, a)| matches!(a, Announce::FastChosen { .. }));
    println!(
        "  {}: chosen={:?} via {}",
        if conflict { "conflicting proposals " } else { "conflict-free proposal" },
        match &chosen {
            Value::Cmd(c) => format!("client {} value {:?}", c.client, c.payload),
            other => format!("{other:?}"),
        },
        if fast { "FAST path (1 round trip)" } else { "coordinated recovery" }
    );
    if !conflict {
        assert!(fast, "conflict-free proposals must take the fast path");
    }
}

fn main() {
    println!("Matchmaker Fast Paxos: f = 1 → 2 acceptors, unanimous P2, singleton P1\n");
    run_scenario(false);
    run_scenario(true);
    println!("\nfast_paxos_demo OK (quorum size f+1 = 2: the theoretical lower bound)");
}
