//! Reconfiguration under load — a condensed Figure 9 (§8.1).
//!
//! 8 closed-loop clients; one acceptor reconfiguration per second between
//! 10 s and 20 s; an acceptor failure at 25 s; a replacement
//! reconfiguration at 30 s. Prints the sliding-window latency/throughput
//! timeline and the Table-1-style before/during comparison — then repeats
//! the reconfiguration-under-load measurement with an *open-loop*
//! pipelined workload, which is how related reconfiguration work reports
//! steady-state impact (offered vs completed rate, not a closed loop's
//! self-limiting throughput).
//!
//! ```sh
//! cargo run --release --example reconfiguration_demo
//! ```

use matchmaker::harness::experiments::{
    run_closed_loop_rate, run_offered_load, run_reconfig_schedule,
};
use matchmaker::harness::secs;
use matchmaker::metrics::interval_summary;
use matchmaker::util::stats;

fn main() {
    println!("running the §8.1 schedule (35 simulated seconds, f=1, 8 clients, thrifty)...\n");
    let run = run_reconfig_schedule(1, 8, true, 42, secs(35));

    println!("t_sec\tmedian_ms\tp95_ms\tthroughput");
    let tl = &run.timeline;
    for i in (0..tl.t.len()).step_by(4) {
        let marker = match tl.t[i] {
            t if (10.0..20.0).contains(&t) => "  <- reconfiguring 1/s",
            t if (25.0..26.0).contains(&t) => "  <- acceptor FAILED",
            t if (30.0..31.0).contains(&t) => "  <- replaced via reconfig",
            _ => "",
        };
        println!(
            "{:>5.1}\t{:>9.3}\t{:>6.3}\t{:>10.0}{}",
            tl.t[i], tl.median_ms[i], tl.p95_ms[i], tl.throughput[i], marker
        );
    }

    let a = interval_summary(&run.samples, 0, secs(10)).unwrap();
    let b = interval_summary(&run.samples, secs(10), secs(20)).unwrap();
    println!("\nTable-1 style comparison (8 clients):");
    println!("                 [0,10)s   [10,20)s   (10 reconfigs in the second window)");
    println!(
        "latency median   {:>7.3}    {:>7.3} ms   ({:+.1}%)",
        a.latency.median,
        b.latency.median,
        100.0 * (b.latency.median - a.latency.median) / a.latency.median
    );
    println!(
        "throughput med   {:>7.0}    {:>7.0} c/s  ({:+.1}%)",
        a.throughput.median,
        b.throughput.median,
        100.0 * (b.throughput.median - a.throughput.median) / a.throughput.median
    );

    let act: Vec<f64> = run.reconfig_latencies.iter().map(|(a, _)| *a).collect();
    let ret: Vec<f64> = run.reconfig_latencies.iter().filter_map(|(_, r)| *r).collect();
    if let (Some(sa), Some(sr)) = (stats(&act), stats(&ret)) {
        println!(
            "\nreconfig → new config ACTIVE: median {:.2} ms (paper: ~1 ms)",
            sa.median
        );
        println!(
            "reconfig → old config RETIRED: median {:.2} ms (paper: ~5 ms)",
            sr.median
        );
    }
    println!(
        "max |H_i| returned by matchmakers: {} (paper: \"only one configuration is ever returned\")",
        run.max_prior_configs
    );

    // The same cluster under open-loop load (reconfiguration at 2 s):
    // offered vs completed rate and the p99 tail, with and without
    // client-side pipelining, against the closed-loop ceiling.
    println!("\nopen-loop reconfiguration-under-load comparison (8 clients, 4 s):");
    let closed = run_closed_loop_rate(8, 1, 42, secs(4));
    println!("  closed-loop ceiling (window 1):        {closed:>8.0} cmds/s");
    for (label, window) in [("open loop, window 1 ", 1usize), ("open loop, window 16", 16)] {
        let s = run_offered_load(8, 3000.0, window, false, 42, secs(4));
        println!(
            "  {label}: offered {:>8.0}/s -> completed {:>8.0}/s (delivered {:>4.0}%, p99 {:.2} ms)",
            s.offered_per_sec,
            s.completed_per_sec,
            100.0 * s.delivery_ratio,
            s.latency.p99
        );
    }
}
