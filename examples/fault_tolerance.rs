//! Fault tolerance: the §8.3 "everything fails at once" experiment
//! (Figure 20) — leader, acceptor, and matchmaker all crash at 7 s, then
//! the system heals stage by stage: leader election, acceptor
//! reconfiguration, matchmaker reconfiguration.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use matchmaker::config::Configuration;
use matchmaker::harness::{secs, Cluster};
use matchmaker::metrics::timeline;
use matchmaker::node::Announce;
use matchmaker::roles::Leader;
use matchmaker::{NodeId, SEC, MS};

fn main() {
    let mut cluster = Cluster::builder().f(1).clients(8).seed(7).build();
    let p0 = cluster.layout.proposers[0];
    let p1 = cluster.layout.proposers[1];
    let dead_acc = cluster.layout.acceptor_pool[0];
    let dead_mm = cluster.layout.matchmaker_pool[0];

    // The follower takes over 4 s after heartbeats stop (paper: arbitrary).
    if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
        l.timing.election_timeout = secs(4);
    }

    println!("t=7s: crash leader {p0}, acceptor {dead_acc}, matchmaker {dead_mm}");
    cluster.sim.schedule(secs(7), move |s| {
        s.crash(p0);
        s.crash(dead_acc);
        s.crash(dead_mm);
    });

    // t=17s: new leader reconfigures away from the dead acceptor.
    let healthy: Vec<NodeId> = cluster
        .layout
        .acceptor_pool
        .iter()
        .copied()
        .filter(|&a| a != dead_acc)
        .take(3)
        .collect();
    let cfg = Configuration::majority(50, healthy.clone());
    cluster.sim.schedule(secs(17), move |s| {
        s.with_node::<Leader, _>(p1, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });

    // t=22s: and away from the dead matchmaker.
    let healthy_mm: Vec<NodeId> = cluster
        .layout
        .matchmaker_pool
        .iter()
        .copied()
        .filter(|&m| m != dead_mm)
        .take(3)
        .collect();
    cluster.sim.schedule(secs(22), move |s| {
        s.with_node::<Leader, _>(p1, |l, now, fx| {
            l.reconfigure_matchmakers(healthy_mm.clone(), now, fx)
        });
    });

    cluster.sim.run_until(secs(25));
    cluster.assert_safe();

    let samples = cluster.samples();
    let tl = timeline(&samples, secs(25), SEC, 500 * MS);
    println!("\nt_sec\tthroughput\tmedian_ms");
    for i in 0..tl.t.len() {
        let marker = match tl.t[i] {
            t if (7.0..8.0).contains(&t) => "  <- triple failure",
            t if (11.0..12.5).contains(&t) => "  <- new leader elected",
            t if (17.0..18.0).contains(&t) => "  <- acceptor reconfig",
            t if (22.0..23.0).contains(&t) => "  <- matchmaker reconfig (no impact)",
            _ => "",
        };
        println!("{:>5.1}\t{:>10.0}\t{:>9.3}{}", tl.t[i], tl.throughput[i], tl.median_ms[i], marker);
    }

    // Verify the healing milestones actually happened.
    let elected = cluster.sim.announces.iter().any(|(t, n, a)| {
        matches!(a, Announce::LeaderSteady { .. }) && *n == p1 && *t > secs(10)
    });
    let mm_reconfigured = cluster
        .sim
        .announces
        .iter()
        .any(|(_, _, a)| matches!(a, Announce::MatchmakersReconfigured { .. }));
    assert!(elected, "new leader must become steady");
    assert!(mm_reconfigured, "matchmaker reconfiguration must complete");
    println!("\nall milestones reached; safety invariant holds — fault_tolerance OK");
}
